"""Tests for the Copperhead-style DSL (paper §6.3)."""

import numpy as np
import pytest

from repro.core.dsl import cu, op_add, op_max


@cu
def axpy(a, x, y):              # the paper's Fig. 7 program, verbatim shape
    def triad(xi, yi):
        return a * xi + yi
    return map(triad, x, y)


@cu
def dotp(x, y):
    def mul(xi, yi):
        return xi * yi
    return reduce(op_add, map(mul, x, y), 0.0)


@cu
def spmv_ell(data, idx, x):     # Table 2's ELL SpMV as nested map/reduce
    def row(d, j):
        def term(dk, jk):
            return dk * gather(x, jk)
        return reduce(op_add, map(term, d, j), 0.0)
    return map(row, data, idx)


@cu
def running_max(x):
    return scan(op_add, x)


def test_axpy():
    a = np.float32(1.5)
    x = np.random.randn(1000).astype(np.float32)
    y = np.random.randn(1000).astype(np.float32)
    np.testing.assert_allclose(axpy(a, x, y), a * x + y, rtol=1e-5, atol=1e-6)


def test_dot():
    x = np.random.randn(512).astype(np.float32)
    y = np.random.randn(512).astype(np.float32)
    assert float(dotp(x, y)) == pytest.approx(float(x @ y), abs=1e-2)


def test_spmv_ell():
    R, K, N = 64, 5, 50
    data = np.random.randn(R, K).astype(np.float32)
    idx = np.random.randint(0, N, (R, K)).astype(np.int32)
    x = np.random.randn(N).astype(np.float32)
    ref = (data * x[idx]).sum(1)
    np.testing.assert_allclose(spmv_ell(data, idx, x), ref, rtol=1e-4, atol=1e-5)


def test_scan():
    x = np.random.randn(100).astype(np.float32)
    np.testing.assert_allclose(running_max(x), np.cumsum(x), rtol=1e-4, atol=1e-4)


def test_generated_source_is_exposed():
    # RTCG: the DSL emits inspectable source and routes it through the
    # content-addressed SourceModule
    assert "jax.vmap(triad)" in axpy.source
    assert "jnp.sum" in dotp.source


def test_unsupported_reduce_op():
    with pytest.raises(NotImplementedError):
        @cu
        def bad(x):
            return reduce(frobnicate, x, 0.0)  # noqa: F821
