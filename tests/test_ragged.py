"""Ragged coalescing tests (PR 9) — per-row runtime lengths through the
kernel layer, the runtime's ragged families, and the executor's
mixed-length flush.

The acceptance sweep: mixed row lengths straddling a column-bucket edge
(N in {1023, 1024, 1025}) execute as ONE 2-launch flush on BOTH
backends, match per-row unfused references exactly where each row is
real, and changing only the length mix inside a bucket rebuilds
nothing.
"""

import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import runtime as rtm
from repro.core import backends, dispatch
from repro.core.cache import DiskCache
from repro.core.elementwise import ElementwiseKernel
from repro.core.platform import BroadcastArg, VectorArg
from repro.core.reduction import ReductionKernel

rng = np.random.default_rng(17)

BOUNDARY_LENS = [1023, 1024, 1025]  # straddles the 1024-col bucket edge


def _softmax_wave(be=None):
    return ReductionKernel(
        [jnp.float32, jnp.float32], ["-3.4e38", "0"],
        ["fmaxf(a, b)", "a + b"], ["x[i]", "expf(x[i] - _acc0)"],
        "float *x", axis=-1, backend=be)


def _pad_stack(rows):
    width = max(r.shape[0] for r in rows)
    X = np.zeros((len(rows), width), np.float32)
    for i, r in enumerate(rows):
        X[i, :r.shape[0]] = r
    return jnp.asarray(X), np.asarray([r.shape[0] for r in rows], np.int32)


# ------------------------------------------------- kernel-layer ragged
@pytest.mark.parametrize("be", ("pallas", "xla"))
def test_ragged_reduction_boundary_sweep(be):
    """Mixed lengths straddling the bucket edge: ONE padded operand, one
    ragged wave, every row reduced over exactly its own length."""
    rows = [rng.standard_normal(L).astype(np.float32) for L in BOUNDARY_LENS]
    X, lens = _pad_stack(rows)
    r0, r1 = _softmax_wave(be)(X, row_lens=lens)
    for i, r in enumerate(rows):
        assert np.asarray(r0)[i] == pytest.approx(r.max(), abs=1e-5)
        assert np.asarray(r1)[i] == pytest.approx(
            np.exp(r - r.max()).sum(), rel=1e-4)


@pytest.mark.parametrize("be", ("pallas", "xla"))
def test_ragged_two_launches_and_parity(be):
    """The full ragged pair (wave + masked epilogue) is exactly 2
    launches and matches the per-row unfused softmax on each row's
    true-length prefix."""
    rows = [rng.standard_normal(L).astype(np.float32) for L in BOUNDARY_LENS]
    X, lens = _pad_stack(rows)
    wave = _softmax_wave(be)
    epi = ElementwiseKernel(
        [BroadcastArg(jnp.float32, "r0", "row"),
         BroadcastArg(jnp.float32, "r1", "row"),
         VectorArg(jnp.float32, "x"), VectorArg(jnp.float32, "out")],
        "out[i] = expf(x[i] - r0) / r1", layout="rows", backend=be)
    # build once outside the counted window
    r0, r1 = wave(X, row_lens=lens)
    epi(r0, r1, X, X, row_lens=lens)
    with dispatch.count_launches() as c:
        r0, r1 = wave(X, row_lens=lens)
        out = np.asarray(epi(r0, r1, X, X, row_lens=lens))
    assert c.delta == 2, c.by_backend
    for i, r in enumerate(rows):
        ref = np.asarray(jax.nn.softmax(jnp.asarray(r)))
        np.testing.assert_allclose(out[i, :r.shape[0]], ref, atol=1e-5)
        # masked columns come back zeroed, not as softmax of garbage
        np.testing.assert_allclose(out[i, r.shape[0]:], 0.0, atol=0.0)


@pytest.mark.parametrize("be", ("pallas", "xla"))
def test_length_mix_change_rebuilds_nothing(be):
    """Lengths are a runtime operand: any mix inside the same (rows,
    cols) bucket reuses the SAME compiled ragged drivers."""
    wave = _softmax_wave(be)
    X = jnp.asarray(rng.standard_normal((4, 1024)).astype(np.float32))
    wave(X, row_lens=np.asarray([1024, 512, 7, 1], np.int32))
    with dispatch.count_compiles() as cc:
        for mix in ([1, 2, 3, 4], [1000, 1024, 3, 900], [512] * 4):
            wave(X, row_lens=np.asarray(mix, np.int32))
    assert cc.delta == 0, cc.by_backend


def test_ragged_and_dense_keys_do_not_collide():
    """A ragged call and a dense call of the same geometry build two
    distinct drivers (the ragged one takes the lengths operand), and
    the bucket signature carries the ragged marker."""
    assert dispatch.rc_bucket(4, 1024) + ("R",) == \
        dispatch.rc_bucket(4, 1024, ragged=True)
    wave = _softmax_wave("pallas")
    X = jnp.asarray(rng.standard_normal((4, 256)).astype(np.float32))
    wave(X)  # dense build
    with dispatch.count_compiles() as cc:
        wave(X, row_lens=np.asarray([256, 100, 5, 1], np.int32))
    assert cc.delta >= 1  # ragged variant is its own driver
    with dispatch.count_compiles() as cc2:
        wave(X)  # dense driver still cached
    assert cc2.delta == 0


def test_ragged_requires_row_axis():
    full = ReductionKernel(jnp.float32, "0", "a + b", "x[i]",
                           "float *x", backend="pallas")  # axis=None
    with pytest.raises(ValueError):
        full(jnp.ones((4,), jnp.float32), row_lens=np.asarray([4], np.int32))
    col_wave = ReductionKernel(jnp.float32, "0", "a + b", "x[i]",
                               "float *x", axis=0, backend="pallas")
    with pytest.raises(ValueError):
        col_wave(jnp.ones((4, 8), jnp.float32),
                 row_lens=np.asarray([8] * 4, np.int32))
    flat = ElementwiseKernel("float *x, float *z", "z[i] = x[i]",
                             backend="pallas")
    with pytest.raises(ValueError):
        flat(jnp.ones((8,), jnp.float32), jnp.ones((8,), jnp.float32),
             row_lens=np.asarray([8], np.int32))


def test_dense_ir_meta_unchanged():
    """Adding the ragged lowering must not perturb dense IR tokens (the
    schema version did not bump; cached dense sequences stay valid)."""
    from repro.core import ir
    from repro.core.backends.base import ElementwiseSpec

    spec = ElementwiseSpec(
        name="t", arg_meta=(("x", "float32", "vector"),
                            ("z", "float32", "vector")),
        scalar_names=(), loaded_vectors=("x",), body_lines=("z = x",),
        out_names=("z",), out_dtypes=("float32",), needs_i=False,
        preamble="", interpret=True)
    dense = ir.lower_elementwise(spec, rows=4, lanes=128, layout="rows")
    ragged = ir.lower_elementwise(spec, rows=4, lanes=128, layout="rows",
                                  ragged=True)
    assert "ragged" not in dict(dense.meta)
    assert dict(ragged.meta)["ragged"] is True
    assert dense.cache_key() != ragged.cache_key()


# ------------------------------------------------- runtime ragged path
@pytest.fixture
def rt(tmp_path):
    r = rtm.ServingRuntime(
        backend="auto", window=0.25, max_batch=8,
        router=rtm.BackendRouter(),
        manifest=rtm.WarmStartManifest(
            cache=DiskCache("ragged_manifest", root=tmp_path)))
    yield r
    r.close()


def _submit_wave(rows, submit):
    futs = [None] * len(rows)

    def one(i):
        futs[i] = submit(rows[i])

    threads = [threading.Thread(target=one, args=(i,)) for i in range(len(rows))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return [f.result(timeout=120) for f in futs]


def test_mixed_lengths_one_flush_two_launches(rt):
    """The tentpole claim at the runtime layer: softmax rows of three
    different lengths straddling a bucket edge flush ONCE (2 launches),
    where length-keyed coalescing would need three flushes (6)."""
    rows = [rng.standard_normal(L).astype(np.float32) for L in BOUNDARY_LENS]
    with dispatch.count_launches() as c:
        outs = _submit_wave(rows, lambda r: rt.submit_softmax(r, ragged=True))
    assert c.delta == 2, c.by_backend
    ex = rt.executor.stats()
    assert ex["flushes"] == 1 and ex["requests"] == len(rows)
    for out, r in zip(outs, rows):
        out = np.asarray(out)
        assert out.shape == r.shape  # true-length prefix, padding stripped
        np.testing.assert_allclose(
            out, np.asarray(jax.nn.softmax(jnp.asarray(r))), atol=1e-5)


def test_ragged_sampler_cdf_fused(rt):
    """submit_sample coalesces mixed-length logits rows into one ragged
    softmax.cdf flush: 2 launches for K rows, the device epilogue
    returning each row's inclusive CDF (monotone, ending at ~1)."""
    lens = [700, 1024, 33]
    rows = [rng.standard_normal(L).astype(np.float32) for L in lens]
    keys = [jax.random.PRNGKey(i) for i in range(len(rows))]
    with dispatch.count_launches() as c:
        futs = [rt.submit_sample(r, k) for r, k in zip(rows, keys)]
        rt.flush()
        toks = [f.result(timeout=120) for f in futs]
    assert c.delta == 2, c.by_backend
    for t, L in zip(toks, lens):
        assert 0 <= t < L
    # CDF correctness through the direct ragged batch path
    X, lv = _pad_stack(rows)
    cdf = np.asarray(rt._run_batch("softmax.cdf", X, {}, row_lens=lv))
    for i, r in enumerate(rows):
        p = np.asarray(jax.nn.softmax(jnp.asarray(r)))
        np.testing.assert_allclose(cdf[i, :r.shape[0]], np.cumsum(p),
                                   atol=1e-4)


def test_ragged_rmsnorm_true_length_mean(rt):
    """Ragged rmsnorm normalizes by each row's true length, not the
    padded bucket width."""
    lens = [300, 512]
    rows = [rng.standard_normal(L).astype(np.float32) for L in lens]
    w = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    outs = _submit_wave(rows, lambda r: rt.submit_rmsnorm(r, w, ragged=True))
    for out, r in zip(outs, rows):
        L = r.shape[0]
        ref = r / np.sqrt((r * r).mean() + 1e-6) * np.asarray(w)[:L]
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4,
                                   atol=1e-5)


def test_ragged_warm_restart_compiles_nothing(rt, tmp_path):
    """Manifest entries recorded with ragged params replay the ragged
    drivers: a restarted process serves the same mixed-length traffic
    with zero driver compiles."""
    rows = [rng.standard_normal(L).astype(np.float32) for L in BOUNDARY_LENS]
    _submit_wave(rows, lambda r: rt.submit_softmax(r, ragged=True))
    dispatch.clear()
    rt2 = rtm.ServingRuntime(
        backend="auto", window=0.25, max_batch=8,
        router=rtm.BackendRouter(),
        manifest=rtm.WarmStartManifest(
            cache=DiskCache("ragged_manifest", root=tmp_path)))
    try:
        rt2.warmup()
        with dispatch.count_compiles() as cc:
            _submit_wave(rows, lambda r: rt2.submit_softmax(r, ragged=True))
        assert cc.delta == 0, cc.by_backend
    finally:
        rt2.close()


def test_ragged_router_bucket_is_distinct(rt):
    """Ragged flushes observe router EMA cells suffixed with the ragged
    marker — they never pollute the dense cells of the same bucket."""
    rows = [rng.standard_normal(512).astype(np.float32) for _ in range(2)]
    for _ in range(2):   # first wave may compile (compiling calls skip EMA)
        _submit_wave(rows, lambda r: rt.submit_softmax(r, ragged=True))
        _submit_wave(rows, rt.submit_softmax)  # dense
    cells = {bucket for (fam, bucket) in rt.router.route_table()
             if fam == "softmax"}
    ragged_cells = {b for b in cells if b and b[-1] == "R"}
    dense_cells = cells - ragged_cells
    assert ragged_cells and dense_cells
