"""CI smoke for the benchmark harness (marked slow).

Runs ``benchmarks.run --only fusion`` in a subprocess on small sizes and
checks the machine-readable BENCH_fusion.json contract: rows carry
(name, us_per_call) plus launch bookkeeping, and the fused map-reduce
path really is one generated-kernel launch vs two unfused.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_fusion_suite_emits_json(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "fusion",
         "--repeats", "1", "--sizes", "20000", "--json-dir", str(tmp_path)],
        cwd=str(REPO), env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]

    out = tmp_path / "BENCH_fusion.json"
    assert out.exists(), "BENCH_fusion.json not written"
    payload = json.loads(out.read_text())
    assert payload["suite"] == "fusion"
    assert payload["compile_count"] >= 1 and payload["launch_count"] >= 1
    rows = {r["name"]: r for r in payload["rows"]}
    fused = rows["fusion.n20000.mapreduce_fused"]
    unfused = rows["fusion.n20000.mapreduce_unfused"]
    assert fused["kernels_launched"] == 1
    assert unfused["kernels_launched"] == 2
    assert fused["us_per_call"] > 0 and "speedup" in fused


@pytest.mark.slow
def test_softmax_suite_emits_json(tmp_path):
    """Planner v2 smoke: the softmax suite writes BENCH_softmax.json and
    the fused schedule really is reduce + ONE epilogue (2 launches) vs 3."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "softmax",
         "--repeats", "1", "--sizes", "20000", "--json-dir", str(tmp_path)],
        cwd=str(REPO), env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]

    payload = json.loads((tmp_path / "BENCH_softmax.json").read_text())
    rows = {r["name"]: r for r in payload["rows"]}
    fused = rows["softmax.n20000.fused"]
    unfused = rows["softmax.n20000.unfused"]
    assert fused["kernels_launched"] == 2
    assert unfused["kernels_launched"] == 3
    assert fused["us_per_call"] > 0 and "speedup" in fused
