"""CI smoke for the benchmark harness (marked slow).

Runs ``benchmarks.run --only fusion`` in a subprocess on small sizes and
checks the machine-readable BENCH_fusion.json contract: rows carry
(name, us_per_call) plus launch bookkeeping, and the fused map-reduce
path really is one generated-kernel launch vs two unfused.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_fusion_suite_emits_json(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "fusion",
         "--repeats", "1", "--sizes", "20000", "--json-dir", str(tmp_path)],
        cwd=str(REPO), env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]

    out = tmp_path / "BENCH_fusion.json"
    assert out.exists(), "BENCH_fusion.json not written"
    payload = json.loads(out.read_text())
    assert payload["suite"] == "fusion"
    assert payload["compile_count"] >= 1 and payload["launch_count"] >= 1
    rows = {r["name"]: r for r in payload["rows"]}
    fused = rows["fusion.n20000.mapreduce_fused"]
    unfused = rows["fusion.n20000.mapreduce_unfused"]
    assert fused["kernels_launched"] == 1
    assert unfused["kernels_launched"] == 2
    assert fused["us_per_call"] > 0 and "speedup" in fused


@pytest.mark.slow
def test_softmax_suite_emits_json(tmp_path):
    """Planner smoke: the softmax suite writes BENCH_softmax.json; the
    flat fused schedule is reduce + ONE epilogue (2 launches) vs 3, and
    the *batched* (B, N) schedule — stable included — is 2 launches for
    the whole batch vs 3·B per-row launches."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "softmax",
         "--repeats", "1", "--sizes", "20000", "--batches", "8x512",
         "--json-dir", str(tmp_path)],
        cwd=str(REPO), env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]

    payload = json.loads((tmp_path / "BENCH_softmax.json").read_text())
    rows = {r["name"]: r for r in payload["rows"]}
    fused = rows["softmax.n20000.fused"]
    unfused = rows["softmax.n20000.unfused"]
    assert fused["kernels_launched"] == 2
    assert unfused["kernels_launched"] == 3
    assert fused["us_per_call"] > 0 and "speedup" in fused
    batched = rows["softmax.b8x512.fused"]
    stable = rows["softmax.b8x512.fused_stable"]
    per_row = rows["softmax.b8x512.unfused"]
    assert batched["kernels_launched"] == 2
    assert stable["kernels_launched"] == 2
    assert per_row["kernels_launched"] == 3 * 8


@pytest.mark.slow
def test_rmsnorm_suite_emits_json(tmp_path):
    """Axis-aware smoke: BENCH_rmsnorm.json carries fused (2-launch
    planner) vs pallas (hand-written kernel) vs unfused rows."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "rmsnorm",
         "--repeats", "1", "--batches", "8x512", "--json-dir", str(tmp_path)],
        cwd=str(REPO), env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]

    payload = json.loads((tmp_path / "BENCH_rmsnorm.json").read_text())
    rows = {r["name"]: r for r in payload["rows"]}
    assert rows["rmsnorm.b8x512.fused"]["kernels_launched"] == 2
    assert rows["rmsnorm.b8x512.unfused"]["kernels_launched"] == 3
    assert "speedup" in rows["rmsnorm.b8x512.fused"]
    assert rows["rmsnorm.b8x512.pallas"]["us_per_call"] > 0


@pytest.mark.slow
def test_serving_suite_emits_json(tmp_path):
    """Serving-runtime smoke (PR 5): BENCH_serving.json carries the
    coalesced-vs-per-request rows (2 launches vs 2·K, >=1.5x), the
    auto-vs-pinned routing rows, and the warm-start row whose replay
    compile count MUST be zero (the suite hard-asserts it too)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_CACHE_DIR"] = str(tmp_path / "cache")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "serving",
         "--repeats", "1", "--batches", "8x512", "--json-dir", str(tmp_path)],
        cwd=str(REPO), env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]

    payload = json.loads((tmp_path / "BENCH_serving.json").read_text())
    rows = {r["name"]: r for r in payload["rows"]}
    coal = rows["serving.k8x512.coalesced"]
    per = rows["serving.k8x512.per_request"]
    assert coal["kernels_launched"] == 2
    assert per["kernels_launched"] == 2 * 8
    assert coal["coalesce_factor"] == 8.0
    assert coal["gate"] is True and "speedup" in coal
    auto = rows["serving.k8x512.auto"]
    assert auto["backend"] == "auto" and auto["routed_to"] in ("pallas", "xla")
    assert "serving.k8x512.pinned.pallas" in rows
    assert "serving.k8x512.pinned.xla" in rows
    warm = rows["serving.k8x512.warmstart"]
    assert warm["replay_compiles"] == 0          # the warmup-leg contract
    assert warm["cold_compiles"] > 0
    assert warm["manifest_entries"] >= 1


@pytest.mark.slow
def test_chaos_suite_emits_json(tmp_path):
    """Fault-tolerance smoke (PR 6): BENCH_chaos.json carries
    availability rows at 0/1/10% injected fault rates (all 1.0 — the
    suite hard-asserts it), the fault-free ladder-overhead row (<=5%),
    and the backend-down row (pallas 100% dead, still 100% served)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_CACHE_DIR"] = str(tmp_path / "cache")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "chaos",
         "--repeats", "1", "--batches", "4x256", "--json-dir", str(tmp_path)],
        cwd=str(REPO), env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]

    payload = json.loads((tmp_path / "BENCH_chaos.json").read_text())
    rows = {r["name"]: r for r in payload["rows"]}
    for rate in (0, 1, 10):
        row = rows[f"chaos.k4x256.rate{rate}"]
        assert row["availability"] == 1.0 and row["gate"] is True
    assert rows["chaos.k4x256.rate10"]["injected_faults"] > 0
    assert rows["chaos.k4x256.overhead"]["overhead_frac"] <= 0.05
    down = rows["chaos.k4x256.backend_down"]
    assert down["availability"] == 1.0 and down["failovers"] > 0


def test_compare_rows_gate():
    """`benchmarks.run --compare` contract: fused rows regressing >tol
    fail, baselines and one-sided rows don't."""
    from benchmarks.run import compare_rows

    committed = {"rows": [
        {"name": "softmax.b64x4096.fused", "us_per_call": 100.0, "speedup": 10.0},
        {"name": "softmax.b64x4096.unfused", "us_per_call": 1000.0},
    ]}
    same = compare_rows(committed, committed)
    assert same == []
    regressed = {"rows": [
        {"name": "softmax.b64x4096.fused", "us_per_call": 100.0, "speedup": 7.0},
        {"name": "softmax.b64x4096.unfused", "us_per_call": 5000.0},
    ]}
    probs = compare_rows(regressed, committed, tol=0.20)
    assert len(probs) == 1 and "softmax.b64x4096.fused" in probs[0]
    # within tolerance -> clean; unfused rows never gate
    ok = {"rows": [
        {"name": "softmax.b64x4096.fused", "us_per_call": 100.0, "speedup": 8.5},
        {"name": "softmax.b64x4096.unfused", "us_per_call": 9000.0},
    ]}
    assert compare_rows(ok, committed, tol=0.20) == []
    # rows present on one side only are skipped, not regressions
    extra = {"rows": [{"name": "softmax.b1x64.fused", "us_per_call": 1.0,
                       "speedup": 2.0}]}
    assert compare_rows(extra, committed) == []
    # us_per_call fallback when speedup is absent on either side
    old_abs = {"rows": [{"name": "x.fused", "us_per_call": 100.0}]}
    new_abs = {"rows": [{"name": "x.fused", "us_per_call": 130.0}]}
    assert len(compare_rows(new_abs, old_abs, tol=0.20)) == 1
    # a fused row needing MORE launches fails at ANY tolerance: the
    # launch schedule is the fusion contract and is noise-free
    old_l = {"rows": [{"name": "y.fused", "us_per_call": 10.0,
                       "speedup": 5.0, "kernels_launched": 2}]}
    new_l = {"rows": [{"name": "y.fused", "us_per_call": 10.0,
                       "speedup": 5.0, "kernels_launched": 4}]}
    probs = compare_rows(new_l, old_l, tol=10.0)
    assert len(probs) == 1 and "schedule regressed" in probs[0]
    # gate=true rows participate without the .fused naming convention
    # (BENCH_serving.json's coalesced/auto rows, PR 5) — speedup AND
    # launch-schedule checks both apply; ungated serving rows never gate
    old_s = {"rows": [
        {"name": "serving.k16x4096.coalesced", "us_per_call": 100.0,
         "speedup": 2.0, "kernels_launched": 2, "gate": True},
        {"name": "serving.k16x4096.per_request", "us_per_call": 200.0},
    ]}
    regressed_s = {"rows": [
        {"name": "serving.k16x4096.coalesced", "us_per_call": 100.0,
         "speedup": 1.2, "kernels_launched": 2, "gate": True},
        {"name": "serving.k16x4096.per_request", "us_per_call": 9000.0},
    ]}
    probs = compare_rows(regressed_s, old_s, tol=0.20)
    assert len(probs) == 1 and "coalesced" in probs[0]
    desched = {"rows": [
        {"name": "serving.k16x4096.coalesced", "us_per_call": 100.0,
         "speedup": 2.0, "kernels_launched": 32, "gate": True}]}
    probs = compare_rows(desched, old_s, tol=10.0)
    assert len(probs) == 1 and "schedule regressed" in probs[0]
    assert compare_rows(old_s, old_s) == []
    # availability rows (chaos suite, PR 6) gate on availability ALONE,
    # zero tolerance — latency under injected faults never gates
    old_a = {"rows": [{"name": "chaos.k16x1024.rate10", "us_per_call": 50.0,
                       "availability": 1.0, "gate": True}]}
    bad_a = {"rows": [{"name": "chaos.k16x1024.rate10", "us_per_call": 40.0,
                       "availability": 0.97, "gate": True}]}
    probs = compare_rows(bad_a, old_a, tol=10.0)
    assert len(probs) == 1 and "availability" in probs[0]
    slow_a = {"rows": [{"name": "chaos.k16x1024.rate10",
                        "us_per_call": 5000.0, "availability": 1.0,
                        "gate": True}]}
    assert compare_rows(slow_a, old_a, tol=0.0) == []
