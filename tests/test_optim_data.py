"""Optimizers, schedules, gradient compression, data pipeline."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.data.pipeline import SyntheticLM
from repro.optim.compress import (compress_with_feedback, dequantize,
                                  init_residual, quantize)
from repro.optim.optimizers import (clip_by_global_norm, cosine_schedule,
                                    global_norm, make_adafactor, make_adamw)


def test_adamw_optimizes_quadratic():
    opt = make_adamw(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}    # d/dw of w^2
        params, state = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adafactor_optimizes_quadratic_matrix():
    opt = make_adafactor(lr=0.05)
    params = {"w": jnp.ones((8, 4)) * 3.0}
    state = opt.init(params)
    assert "vr" in jax.tree.leaves(state["slots"], is_leaf=lambda x: isinstance(x, dict) and "vr" in x)[0]
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_adafactor_state_is_factored():
    opt = make_adafactor()
    p = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    st_ = opt.init(p)
    assert st_["slots"]["w"]["vr"].shape == (64,)
    assert st_["slots"]["w"]["vc"].shape == (32,)
    assert st_["slots"]["b"]["v"].shape == (64,)


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule():
    s = cosine_schedule(1.0, warmup=10, total=100)
    assert float(s(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(s(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


@given(seed=st.integers(0, 100), bits=st.sampled_from([4, 8]))
@settings(max_examples=10, deadline=None)
def test_quantize_bounded_error(seed, bits):
    g = jax.random.normal(jax.random.PRNGKey(seed), (256,))
    q, scale = quantize(g, bits)
    err = jnp.max(jnp.abs(dequantize(q, scale) - g))
    assert float(err) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_removes_bias():
    """Invariant: with error feedback, the SUM of compressed gradients
    converges to the sum of true gradients (bias does not accumulate)."""
    g_true = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 0.01
    grads = {"w": g_true}
    residual = init_residual(grads)
    acc = jnp.zeros(128)
    for _ in range(50):
        cg, residual = compress_with_feedback(grads, residual)
        acc = acc + cg["w"]
    np.testing.assert_allclose(acc / 50, g_true, atol=5e-4)


# ------------------------------------------------------------------- data
def test_data_deterministic_and_resumable():
    d = SyntheticLM(1000, 32, 4, seed=7)
    b1 = d.batch_at(10)
    b2 = SyntheticLM(1000, 32, 4, seed=7).batch_at(10)  # fresh pipeline
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d.batch_at(11)["tokens"], b1["tokens"])


def test_data_labels_are_shifted_tokens():
    d = SyntheticLM(1000, 16, 2, seed=0)
    b = d.batch_at(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    # label[t] is the next token of an S+1 stream: consecutive windows agree
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_is_learnable_structure():
    d = SyntheticLM(64, 256, 8, seed=0)
    b = d.batch_at(0)
    toks = np.asarray(b["tokens"])
    # affine recurrence: the same current-token value mostly maps to the
    # same next-token value => strictly better than chance predictability
    nxt = {}
    hits = total = 0
    for row in toks:
        for t in range(len(row) - 1):
            cur, n = int(row[t]), int(row[t + 1])
            if cur in nxt:
                total += 1
                hits += (nxt[cur] == n)
            nxt[cur] = n
    # 4-way recurrence noise bounds top-1 predictability near 25%;
    # uniform chance over the 64-token vocab would be ~1.6%
    assert hits / max(total, 1) > 0.15
