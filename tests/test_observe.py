"""Flight recorder + metrics plane (PR 10, DESIGN.md §14).

Four layers, bottom-up:

  * histogram/merge algebra — fixed-edge histograms merge by count sum
    (associative, commutative, exact); percentiles read off merged
    counts within one bucket width; Prometheus text exposition;
  * flight recorder — bounded ring, Chrome trace-event schema
    round-trip, ``sid``/``parent`` parentage;
  * hot-path contract — ``REPRO_TRACE=off`` allocates NOTHING in the
    observe module (tracemalloc-verified), `take_last_rung` is
    read-and-clear;
  * the serving stack — request spans reconstructed through a real
    coalesced flush (admit/queue/reply children, flush backref, serve
    under flush), `merge_stats` folding metrics + kvcache + executor
    counters, and the fleet acceptance run: K=8 over 4 workers with one
    injected worker kill exports ONE merged cross-process trace whose
    ``dispatch`` spans join worker ``serve_group`` spans by gid, and
    ``fleet.stats()`` carries cross-worker p50/p95 per (family,
    backend).
"""

import json
import os
import threading
import tracemalloc

import numpy as np
import pytest

from repro.runtime import observe

# exact-in-binary latencies: histogram ``sum`` fields stay bit-identical
# whatever the merge order, so associativity asserts with ==
V1, V2, V3 = 1.0 / 1024, 1.0 / 512, 1.0 / 256


@pytest.fixture(autouse=True)
def _reset_observe():
    """Leave each test with a clean registry/recorder and the mode the
    process was launched with (the CI obs-smoke leg runs the whole
    suite under REPRO_TRACE=spans — later tests must still see it)."""
    yield
    observe.set_mode("off")
    observe.METRICS.clear()
    observe.RECORDER.clear()
    observe.install_from_env()


# ---------------------------------------------------------------------------
# histogram / merge algebra
# ---------------------------------------------------------------------------

class TestHistogram:
    def test_percentile_within_one_bucket_width(self):
        h = observe.Histogram(observe.LATENCY_EDGES_S)
        for _ in range(50):
            h.observe(0.001)
        for _ in range(50):
            h.observe(0.004)
        assert h.count == 100
        # upper edge of the holding bucket: log2 edges bound the
        # overestimate at 2x
        assert 0.001 <= h.percentile(0.5) <= 0.002
        assert 0.004 <= h.percentile(0.99) <= 0.008
        assert h.percentile(0.5) <= h.percentile(0.95) <= h.percentile(0.99)

    def test_empty_and_snapshot_roundtrip(self):
        h = observe.Histogram(observe.SIZE_EDGES)
        assert h.percentile(0.5) == 0.0
        h.observe(3.0)
        h.observe(1e9)   # beyond the last edge: the +Inf slot
        snap = h.snapshot()
        h2 = observe.Histogram.from_snapshot(snap, observe.SIZE_EDGES)
        assert h2.count == 2 and h2.counts == h.counts
        assert h2.percentile(0.99) == float("inf")


def _doc(vals, n_req):
    r = observe.MetricsRegistry()
    for v in vals:
        r.observe("request_latency_seconds",
                  ("softmax", "xla", "16x16", "none"), v)
    r.inc("requests_total", ("softmax", "xla"), n_req)
    r.wave("softmax", "xla", "16x16", seconds=V1, nbytes=1 << 20, launches=2)
    return r.snapshot()


def test_merge_metrics_associative_and_commutative():
    a = _doc([V1] * 3, 3)
    b = _doc([V2] * 5, 5)
    c = _doc([V3] * 7, 7)
    m1 = observe.merge_metrics(observe.merge_metrics(a, b), c)
    m2 = observe.merge_metrics(a, observe.merge_metrics(b, c))
    m3 = observe.merge_metrics(c, b, a)
    assert m1 == m2 == m3
    s = m1["histograms"]["request_latency_seconds"]["softmax|xla|16x16|none"]
    assert s["count"] == 15 and s["sum"] == 3 * V1 + 5 * V2 + 7 * V3
    assert m1["counters"]["requests_total"]["softmax|xla"] == 15
    prof = m1["profile"]["softmax|xla|16x16"]
    assert prof["calls"] == 3 and prof["launches"] == 6
    assert prof["bytes"] == 3 << 20


def test_latency_summary_collapses_bucket_and_rung():
    r = observe.MetricsRegistry()
    r.observe("request_latency_seconds", ("softmax", "xla", "16x16", "none"),
              V1)
    r.observe("request_latency_seconds", ("softmax", "xla", "8x8",
                                          "degraded"), V3)
    summ = observe.latency_summary(r.snapshot())
    assert set(summ) == {"softmax|xla"}
    e = summ["softmax|xla"]
    assert e["count"] == 2
    assert 0 < e["p50_ms"] <= e["p95_ms"] <= e["p99_ms"]


def test_metrics_text_exposition():
    r = observe.MetricsRegistry()
    r.inc("requests_total", ("softmax", "xla"), 3)
    r.observe("queue_wait_seconds", ("softmax",), V1)
    text = observe.metrics_text(r.snapshot())
    assert "# TYPE repro_requests_total counter" in text
    assert 'repro_requests_total{family="softmax",backend="xla"} 3' in text
    assert "# TYPE repro_queue_wait_seconds histogram" in text
    assert 'repro_queue_wait_seconds_count{family="softmax"} 1' in text
    assert 'repro_queue_wait_seconds_bucket{family="softmax",le="+Inf"} 1' \
        in text
    # cumulative le buckets never decrease
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("repro_queue_wait_seconds_bucket")]
    assert cums == sorted(cums) and cums[-1] == 1
    # empty document renders empty (scrape-friendly, not an error)
    assert observe.metrics_text(
        {"histograms": {}, "counters": {}, "profile": {}}) == ""


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_trace_export_schema_roundtrip(tmp_path):
    observe.RECORDER.clear()
    sid = observe.RECORDER.add("root", "test", 1.0, 2.0)
    observe.RECORDER.add("child", "test", 1.2, 1.5, parent=sid,
                         args={"k": "v"})
    path = tmp_path / "trace.json"
    n = observe.export_trace(path)
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert len(evs) == n == 2
    for e in evs:
        assert e["ph"] == "X"
        assert {"name", "cat", "ts", "dur", "pid", "tid", "args"} <= set(e)
    by = {e["name"]: e for e in evs}
    assert by["child"]["args"]["parent"] == by["root"]["args"]["sid"]
    assert by["child"]["args"]["k"] == "v"
    assert by["root"]["ts"] == 1.0e6 and by["root"]["dur"] == 1.0e6


def test_recorder_ring_is_bounded():
    rec = observe.FlightRecorder(capacity=16)
    for i in range(40):
        rec.add(f"e{i}", "t", 0.0, 0.0)
    st = rec.stats()
    assert st["events"] == 16 and st["capacity"] == 16
    assert st["dropped"] == 24
    # the ring keeps the newest events
    assert rec.events()[-1]["name"] == "e39"


# ---------------------------------------------------------------------------
# hot-path contract
# ---------------------------------------------------------------------------

def test_off_mode_allocates_nothing():
    observe.set_mode("off")
    labels = ("softmax",)

    def hot():
        tok = observe.span_begin()
        observe.span_end(tok, "x", "y")
        observe.count("requests_total", "softmax", "xla")
        observe.observe_hist("queue_wait_seconds", labels, V1)
        observe.record_wave("softmax", "xla", "b", V1, 0, 0)

    for _ in range(16):   # warm any lazy caches
        hot()
    tracemalloc.start()
    try:
        s0 = tracemalloc.take_snapshot()
        for _ in range(1000):
            hot()
        s1 = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    flt = [tracemalloc.Filter(True, observe.__file__)]
    diff = s1.filter_traces(flt).compare_to(s0.filter_traces(flt),
                                            "filename")
    leaked = sum(d.size_diff for d in diff)
    # any per-call allocation would show as >= 16KB over 1000 calls;
    # allow sub-1-byte/call slack for unrelated daemon-thread noise
    # (earlier tests leave supervisor/executor threads behind)
    assert leaked < 1000, \
        f"off-mode hot path allocated {leaked}B/1000 calls in observe"


def test_take_last_rung_is_read_and_clear():
    from repro.core import dispatch

    dispatch._tl_obs.rung = "retry"
    assert dispatch.take_last_rung() == "retry"
    assert dispatch.take_last_rung() is None


def test_observe_block_is_null_without_observer():
    from repro.core import dispatch

    observe.set_mode("off")   # uninstalls the dispatch observer
    blk = dispatch.observe_block("plan", family="softmax")
    assert blk is dispatch._NULL_BLOCK
    with blk:   # and it is a no-op context manager
        pass


def test_set_mode_installs_and_removes_observer():
    from repro.core import dispatch

    prev = observe.set_mode("counters")
    assert observe.mode() == "counters" and dispatch._observer is not None
    observe.set_mode("off")
    assert dispatch._observer is None
    observe.set_mode(prev)
    with pytest.raises(ValueError):
        observe.set_mode("verbose")


def test_stats_server_endpoints():
    from urllib.request import urlopen

    observe.set_mode("counters")
    observe.METRICS.clear()
    observe.count("requests_total", "softmax", "xla")
    srv = observe.StatsServer(port=0)
    try:
        base = srv.url()
        text = urlopen(base + "/metrics", timeout=10).read().decode()
        assert 'repro_requests_total{family="softmax",backend="xla"} 1' \
            in text
        stats = json.loads(urlopen(base + "/stats", timeout=10).read())
        assert "metrics" in stats
        trace = json.loads(urlopen(base + "/trace", timeout=10).read())
        assert "traceEvents" in trace
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# the serving stack
# ---------------------------------------------------------------------------

def test_request_span_parentage_through_flush():
    from repro import runtime as rtm

    observe.set_mode("spans")
    observe.RECORDER.clear()
    K, N = 4, 256
    rt = rtm.ServingRuntime(backend="xla", window=0.25, max_batch=K)
    try:
        rng = np.random.default_rng(0)
        rows = [rng.standard_normal(N).astype(np.float32) for _ in range(K)]
        futs: list = [None] * K

        def sub(i):
            futs[i] = rt.submit_softmax(rows[i])

        ts = [threading.Thread(target=sub, args=(i,)) for i in range(K)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        for f in futs:
            np.testing.assert_allclose(np.asarray(f.result(timeout=300)).sum(),
                                       1.0, atol=1e-4)
    finally:
        rt.close()
    evs = observe.RECORDER.events()
    by_sid = {e["args"]["sid"]: e for e in evs}
    assert {"request", "admit", "queue", "reply", "flush", "serve",
            "plan"} <= {e["name"] for e in evs}
    roots = [e for e in evs if e["name"] == "request"]
    assert len(roots) == K
    for r in roots:
        kids = {e["name"] for e in evs
                if e["args"].get("parent") == r["args"]["sid"]}
        assert {"admit", "queue", "reply"} <= kids
        # the backref onto the flush that actually served this request
        assert by_sid[r["args"]["flush"]]["name"] == "flush"
    # execution nesting on the flush thread: serve under flush, plan
    # under serve
    serves = [e for e in evs if e["name"] == "serve"]
    assert serves
    for s in serves:
        assert by_sid[s["args"]["parent"]]["name"] == "flush"
    plans = [e for e in evs if e["name"] == "plan"]
    assert plans
    assert all(by_sid[p["args"]["parent"]]["name"] == "serve" for p in plans)


def test_merge_stats_folds_metrics_kvcache_executor(tmp_path):
    from repro import runtime as rtm

    observe.set_mode("counters")
    observe.METRICS.clear()
    rt = rtm.ServingRuntime(backend="xla", window=0.05, max_batch=2)
    try:
        X = np.random.default_rng(0).standard_normal((2, 128)).astype(
            np.float32)
        rt.softmax(X, stable=True)
        snap = rt.stats_snapshot()
    finally:
        rt.close()
    n_req = snap["metrics"]["counters"]["requests_total"]["softmax|xla"]
    assert n_req >= 1
    merged = rtm.merge_stats([snap, snap])
    # metrics fold through the histogram merge, not generic numeric sum
    assert merged["metrics"]["counters"]["requests_total"]["softmax|xla"] \
        == 2 * n_req
    hist = merged["metrics"]["histograms"]["request_latency_seconds"]
    assert sum(s["count"] for s in hist.values()) == \
        2 * sum(s["count"] for s in
                snap["metrics"]["histograms"]
                ["request_latency_seconds"].values())
    # the PR 9/PR 10 merge-audit keys survive the fold
    assert "kvcache" in merged and "pools" in merged["kvcache"]
    assert merged["executor"]["requests"] == 2 * snap["executor"]["requests"]
    # and the merged doc grows the cross-worker percentile view
    assert merged["latency"]["softmax|xla"]["count"] == 2 * n_req


@pytest.mark.slow
def test_fleet_merged_trace_and_cross_worker_latency(tmp_path):
    """The PR 10 acceptance run: K=8 over 4 workers with one injected
    worker kill -> ONE merged Chrome trace with per-request
    admit/queue/dispatch/reply parentage, dispatcher ``dispatch`` spans
    joining worker ``serve_group`` spans (other pids) by gid, and
    cross-worker p50/p95 per (family, backend) in ``fleet.stats()``."""
    from repro.runtime.fleet import ServingFleet
    from repro.runtime.supervisor import BackoffPolicy

    observe.set_mode("spans")
    observe.RECORDER.clear()
    observe.METRICS.clear()
    K = 8
    rows = np.random.default_rng(0).standard_normal((K, 128)).astype(
        np.float32)
    fleet = ServingFleet(
        workers=4, backend="xla", max_batch=8,
        cache_dir=str(tmp_path / "fleet-cache"),
        env={"REPRO_TRACE": "spans"},
        chaos_rules=[{"site": "worker.kill", "index": 2, "times": 1}],
        chaos_incarnations=[1], group_max=1, max_outstanding=1,
        max_redispatch=5, backoff=BackoffPolicy(base=0.01, cap=0.1),
        supervisor_tick=0.05)
    try:
        fleet.wait_ready(timeout=300)
        futs = [fleet.submit_softmax(r, deadline=120) for r in rows]
        for f in futs:
            out = np.asarray(f.result(timeout=180))
            assert abs(float(out.sum()) - 1.0) < 1e-3
        st = fleet.stats()
        assert st["fleet"]["deaths"].get("crash", 0) >= 1   # the kill landed
        lat = st["latency"]
        assert "softmax|fleet" in lat, f"latency families: {sorted(lat)}"
        e = lat["softmax|fleet"]
        assert e["count"] == K
        assert 0 < e["p50_ms"] <= e["p95_ms"]
        path = tmp_path / "fleet-trace.json"
        n_ev = fleet.export_trace(path)
    finally:
        fleet.close()
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert len(evs) == n_ev > 0
    roots = [e for e in evs if e["name"] == "request"]
    assert len(roots) == K
    for r in roots:
        kids = {e["name"] for e in evs
                if e["args"].get("parent") == r["args"]["sid"]}
        assert {"admit", "queue", "dispatch", "reply"} <= kids
    # cross-process join: dispatcher dispatch spans resolve to worker
    # serve_group spans by gid.  Spans of the killed incarnations died
    # with their processes (the truthful picture), so not every gid
    # joins — but the surviving timeline must join somewhere.
    main_pid = os.getpid()
    sg = {e["args"].get("gid"): e for e in evs if e["name"] == "serve_group"}
    assert sg and all(e["pid"] != main_pid for e in sg.values())
    joined = [e for e in evs if e["name"] == "dispatch"
              and e["args"].get("gid") in sg]
    assert joined, "no dispatch span joined a worker serve_group span"
