"""Kernel IR tests (DESIGN.md §11) — lowering, transformations, renders.

Covers: golden IR→source renders for softmax- and rmsnorm-shaped
fixtures on BOTH backends (the refactor's byte-identity contract),
transformation algebra (purity, tile∘split commutation on distinct
axes, idempotent tags, transpose_layout involution), content
addressability (cache-key stability and distinctness, transform-log
recording), winner-sequence replay via `ir.apply_sequence`, the
``REPRO_IR_STRICT=1`` dispatch assertion, and the IR schema version in
`cache.environment_fingerprint()`.
"""

import numpy as np
import pytest

from repro.core import cache, dispatch, ir
from repro.core.elementwise import ElementwiseKernel
from repro.core.platform import BroadcastArg, VectorArg
from repro.core.reduction import ReductionKernel


# ------------------------------------------------------------ fixtures
def softmax_wave_kernel():
    """The planner's stable-softmax row wave: multi-accumulator rowmax +
    shifted-exp rowsum with in-wave ``_acc0`` chaining."""
    return ReductionKernel(
        [np.float32, np.float32], ["-3.4028234663852886e+38", "0"],
        ["fmaxf(a,b)", "a+b"], ["x[i]", "expf(x[i] - _acc0)"],
        "float *x", name="softmax_wave", axis=-1)


def softmax_epi_kernel():
    """The softmax epilogue: 2-D row layout with a per-row broadcast."""
    return ElementwiseKernel(
        [BroadcastArg(np.float32, "r0", "row"), VectorArg(np.float32, "x"),
         VectorArg(np.float32, "out")],
        "out[i] = expf(x[i]) / r0", name="softmax_epi", layout="rows")


def rmsnorm_epi_kernel():
    """The rmsnorm epilogue: per-row rms + per-col weight broadcasts."""
    return ElementwiseKernel(
        [BroadcastArg(np.float32, "r0", "row"),
         BroadcastArg(np.float32, "w", "col"),
         VectorArg(np.float32, "x"), VectorArg(np.float32, "out")],
        "out[i] = x[i] / sqrtf(r0 + 1e-6f) * w", name="rmsnorm_epi",
        layout="rows")


# --------------------------------------------------- golden renders
# IR→source goldens at (block_rows=8, ncols=1024).  These pin the
# render byte-for-byte: any IR/lowering change that alters generated
# source must be deliberate (and bump IR_SCHEMA_VERSION).
GOLDEN_WAVE_PALLAS = '''
def softmax_wave_kernel(_n_ref, x_ref, o0_ref, o1_ref):
    _n = _n_ref[0, 0]
    _col = jax.lax.broadcasted_iota(jnp.int32, (8, 1024), 1)
    x = x_ref[...]
    _mapped0 = jnp.asarray(x).astype(jnp.float32)
    _mapped0 = jnp.where(_col < _n, _mapped0, jnp.asarray(-3.4028234663852886e+38, jnp.float32))
    _acc0 = jnp.max(_mapped0, axis=1, keepdims=True)
    o0_ref[...] = _acc0
    _mapped1 = jnp.asarray(jnp.exp(x - _acc0)).astype(jnp.float32)
    _mapped1 = jnp.where(_col < _n, _mapped1, jnp.asarray(0, jnp.float32))
    _acc1 = jnp.sum(_mapped1, axis=1, keepdims=True)
    o1_ref[...] = _acc1
'''

GOLDEN_WAVE_XLA = '''
def softmax_wave_fn(_n_ref, x):
    _n = _n_ref[0, 0]
    _col = jax.lax.broadcasted_iota(jnp.int32, (8, 1024), 1)
    _mapped0 = jnp.asarray(x).astype(jnp.float32)
    _mapped0 = jnp.where(_col < _n, _mapped0, jnp.asarray(-3.4028234663852886e+38, jnp.float32))
    _acc0 = jnp.max(_mapped0, axis=1, keepdims=True)
    _mapped1 = jnp.asarray(jnp.exp(x - _acc0)).astype(jnp.float32)
    _mapped1 = jnp.where(_col < _n, _mapped1, jnp.asarray(0, jnp.float32))
    _acc1 = jnp.sum(_mapped1, axis=1, keepdims=True)
    return (_acc0, _acc1, )'''

GOLDEN_EPI_PALLAS = '''
def softmax_epi_kernel(r0_ref, x_ref, out_ref, out_out_ref):
    _BLK = (8, 1024)
    r0 = r0_ref[...]
    x = x_ref[...]
    out = jnp.broadcast_to(jnp.asarray(jnp.exp(x) / r0), _BLK).astype(jnp.float32)
    out_out_ref[...] = out
'''

GOLDEN_EPI_XLA = '''
def softmax_epi_fn(r0, x, out):
    _BLK = (8, 1024)
    out = jnp.broadcast_to(jnp.asarray(jnp.exp(x) / r0), _BLK).astype(jnp.float32)
    return (out, )'''

GOLDEN_RMS_PALLAS = '''
def rmsnorm_epi_kernel(r0_ref, w_ref, x_ref, out_ref, out_out_ref):
    _BLK = (8, 1024)
    r0 = r0_ref[...]
    w = w_ref[...]
    x = x_ref[...]
    out = jnp.broadcast_to(jnp.asarray(x / jnp.sqrt(r0 + 1e-6) * w), _BLK).astype(jnp.float32)
    out_out_ref[...] = out
'''

GOLDEN_RMS_XLA = '''
def rmsnorm_epi_fn(r0, w, x, out):
    _BLK = (8, 1024)
    out = jnp.broadcast_to(jnp.asarray(x / jnp.sqrt(r0 + 1e-6) * w), _BLK).astype(jnp.float32)
    return (out, )'''

GOLDENS = {
    ("wave", "pallas"): GOLDEN_WAVE_PALLAS,
    ("wave", "xla"): GOLDEN_WAVE_XLA,
    ("epi", "pallas"): GOLDEN_EPI_PALLAS,
    ("epi", "xla"): GOLDEN_EPI_XLA,
    ("rms", "pallas"): GOLDEN_RMS_PALLAS,
    ("rms", "xla"): GOLDEN_RMS_XLA,
}
FIXTURES = {"wave": softmax_wave_kernel, "epi": softmax_epi_kernel,
            "rms": rmsnorm_epi_kernel}


@pytest.mark.parametrize("backend", ["pallas", "xla"])
@pytest.mark.parametrize("fixture", sorted(FIXTURES))
def test_golden_render(fixture, backend):
    src = FIXTURES[fixture]().render(8, 1024, backend=backend)
    assert src == GOLDENS[(fixture, backend)]


# ----------------------------------------------- transformation algebra
def _eltwise_ir(rows=64, lanes=128):
    return ir.lower_elementwise(softmax_epi_kernel().spec,
                                rows=rows, lanes=lanes, layout="rows")


def test_transformations_are_pure():
    base = _eltwise_ir()
    tiled = ir.tile(base, "rows", 8)
    assert tiled is not base
    assert base.transform_log == ()               # input untouched
    assert base.axis("rows").block is None
    assert tiled.axis("rows").block == 8
    assert tiled.transform_log == (
        ("tile", (("axis", "rows"), ("block", 8))),)


def test_tile_split_commute_structurally():
    """tile and split on DISTINCT axes commute: the IRs are structurally
    identical while their transformation chains stay distinguishable."""
    base = _eltwise_ir(rows=64, lanes=256)
    a = ir.split(ir.tile(base, "rows", 8), "lanes", 64)
    b = ir.tile(ir.split(base, "lanes", 64), "rows", 8)
    assert a.structural_token() == b.structural_token()
    assert a.transform_log != b.transform_log
    assert a.cache_token() != b.cache_token()
    assert a.axis("lanes.o").extent == 4 and a.axis("lanes.i").extent == 64


def test_tag_is_idempotent():
    base = _eltwise_ir()
    once = ir.tag_parallel(base, "rows")
    twice = ir.tag_parallel(once, "rows")
    assert twice is once                          # no-op returns the input
    assert once.axis("rows").tag == "parallel"
    assert len(once.transform_log) == 1


def test_transpose_layout_swaps_kinds_and_is_involutive():
    base = ir.lower_reduction(softmax_wave_kernel().spec, rows=8, cols=1024,
                              layout="rows")
    t = ir.transpose_layout(base)
    kinds = {n: k for n, _, k in base.args}
    tkinds = {n: k for n, _, k in t.args}
    assert kinds["x"] == "full" and tkinds["x"] == "full"
    assert t.transposed and not base.transposed
    back = ir.transpose_layout(t)
    assert not back.transposed
    assert back.structural_token() == base.structural_token()
    assert len(back.transform_log) == 2           # the chain remembers


def test_broadcast_kinds_swap_under_transpose():
    base = ir.lower_elementwise(rmsnorm_epi_kernel().spec,
                                rows=8, lanes=1024, layout="rows")
    t = ir.transpose_layout(base)
    kinds = {n: k for n, _, k in t.args}
    assert kinds["r0"] == "col" and kinds["w"] == "row"


def test_cache_key_stability_and_distinctness():
    base = _eltwise_ir()
    k1 = ir.tile(base, "rows", 8).cache_key()
    k2 = ir.tile(base, "rows", 8).cache_key()
    k3 = ir.tile(base, "rows", 16).cache_key()
    assert k1 == k2
    assert k1 != k3
    assert ir.transpose_layout(base).cache_key() != base.cache_key()


def test_apply_sequence_replays_winner_chains():
    from repro.core import autotune

    base = ir.lower_reduction(softmax_wave_kernel().spec, rows=8, cols=1024,
                              layout="rows")
    seq = autotune.sequence_for("block_rows", 16, transposed=True)
    replayed = ir.apply_sequence(base, seq)
    manual = ir.tile(ir.transpose_layout(base), "rows", 16)
    assert replayed.cache_token() == manual.cache_token()
    assert replayed.transposed and replayed.axis("rows").block == 16


def test_describe_includes_domain_and_transforms():
    kir = ir.tile(ir.tag_parallel(_eltwise_ir(), "rows"), "rows", 8)
    text = kir.describe()
    assert "axis rows" in text and "tag=parallel" in text
    assert "tile(axis=rows, block=8)" in text


# ----------------------------------------------------------- strict mode
def test_ir_strict_accepts_ir_built_drivers(monkeypatch):
    monkeypatch.setenv("REPRO_IR_STRICT", "1")
    kern = ElementwiseKernel("float *z, float *x", "z[i] = x[i] + 1",
                             name="strict_probe")
    x = np.arange(300, dtype=np.float32)
    np.testing.assert_allclose(np.asarray(kern(np.empty_like(x), x)), x + 1)


def test_ir_strict_rejects_legacy_string_builders(monkeypatch):
    monkeypatch.setenv("REPRO_IR_STRICT", "1")
    with pytest.raises(AssertionError, match="REPRO_IR_STRICT"):
        dispatch.get_or_build(("legacy_probe", "none", "k"),
                              lambda: (lambda *a: None), backend="pallas",
                              name="legacy_probe", bucket=(1,))


# ----------------------------------------------------- environment tie-in
def test_environment_fingerprint_carries_ir_schema():
    fp = cache.environment_fingerprint()
    assert fp["ir_schema"] == ir.IR_SCHEMA_VERSION
