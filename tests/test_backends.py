"""Backend abstraction tests (PR 4) — one RTCG pipeline, two targets.

Covers: registry/selection (explicit arg, instance passthrough,
``REPRO_BACKEND``), capability fingerprints and backend-sensitive
persistence fingerprints, backend-keyed driver caching (same rendered
source on two backends = two driver-cache entries, two compile counts),
per-backend launch counters (`count_launches().by_backend`), tuning
winners per (backend, bucket), XlaBackend numerics vs PallasBackend
across all three kernel families, and the planner/serving-layer
``backend=`` pass-through.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.core.array as ga
from repro.core import backends, dispatch
from repro.core.backends import PallasBackend, XlaBackend, get_backend
from repro.core.cache import environment_fingerprint, fingerprint_token
from repro.core.elementwise import ElementwiseKernel
from repro.core.reduction import ReductionKernel
from repro.core.scan import ExclusiveScanKernel, InclusiveScanKernel

rng = np.random.default_rng(42)


# ------------------------------------------------------------ selection
def test_registry_and_selection(monkeypatch):
    assert set(backends.available_backends()) >= {"pallas", "xla"}
    assert isinstance(get_backend("pallas"), PallasBackend)
    assert isinstance(get_backend("xla"), XlaBackend)
    # instances are singletons and pass through get_backend
    be = get_backend("xla")
    assert get_backend("xla") is be
    assert get_backend(be) is be
    # default comes from REPRO_BACKEND (default pallas)
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    assert get_backend().name == "pallas"
    monkeypatch.setenv("REPRO_BACKEND", "xla")
    assert get_backend().name == "xla"
    with pytest.raises(ValueError, match="unknown RTCG backend"):
        get_backend("opencl")


def test_fingerprints_differ_across_backends(monkeypatch):
    fp = get_backend("pallas").fingerprint()
    fx = get_backend("xla").fingerprint()
    assert fp != fx and fp["backend"] == "pallas" and fx["backend"] == "xla"
    # persistence fingerprints (cache.py) carry the backend dimension:
    # a pallas-keyed disk entry can never be served to the xla target
    assert environment_fingerprint("pallas") != environment_fingerprint("xla")
    assert fingerprint_token("pallas") != fingerprint_token("xla")
    # the env-resolved form follows REPRO_BACKEND
    monkeypatch.setenv("REPRO_BACKEND", "xla")
    assert environment_fingerprint()["rtcg_backend"] == "xla"
    assert fingerprint_token() == fingerprint_token("xla")
    monkeypatch.setenv("REPRO_BACKEND", "pallas")
    assert fingerprint_token() == fingerprint_token("pallas")


# ------------------------------------------------- backend-keyed caches
def test_driver_cache_is_backend_keyed():
    """Same rendered source on two backends -> two driver-cache entries
    and one compile counted against each backend's tag."""
    k = ElementwiseKernel("float *z, float *x", "z[i] = 3*x[i] + 1",
                          name="bk_cache_probe")
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    size0 = len(dispatch.driver_cache())
    cp0, cx0 = dispatch.compile_count("pallas"), dispatch.compile_count("xla")
    zp = k(x, x, backend="pallas")
    zx = k(x, x, backend="xla")
    assert len(dispatch.driver_cache()) == size0 + 2
    assert dispatch.compile_count("pallas") == cp0 + 1
    assert dispatch.compile_count("xla") == cx0 + 1
    np.testing.assert_allclose(np.asarray(zp), np.asarray(zx), rtol=1e-6)
    # re-calls on either backend are pure cache hits
    c0 = dispatch.compile_count()
    k(x, x, backend="pallas"); k(x, x, backend="xla")
    assert dispatch.compile_count() == c0


def test_launch_counters_tagged_by_backend():
    k = ElementwiseKernel("float *z, float *x", "z[i] = x[i] * x[i]",
                          name="bk_counter_probe")
    x = jnp.asarray(rng.standard_normal(2048).astype(np.float32))
    with dispatch.count_launches() as c:
        k(x, x, backend="pallas")
        k(x, x, backend="xla")
        k(x, x, backend="xla")
    assert c.delta == 3
    assert c.by_backend["pallas"] == 1 and c.by_backend["xla"] == 2
    assert "pallas" in dispatch.launch_counts()
    assert "xla" in dispatch.launch_counts()
    # stats() surfaces the per-backend maps benchmarks record
    s = dispatch.stats()
    assert s["launches_by_backend"]["xla"] >= 2


def test_tuning_winners_per_backend_bucket(tmp_path):
    from repro.core.cache import DiskCache

    k = ElementwiseKernel("float *o, float *v", "o[i] = 2*v[i] - 3",
                          name="bk_tune_probe")
    cache = DiskCache("tune", root=tmp_path)
    v = jnp.asarray(rng.standard_normal(50_000).astype(np.float32))
    rp = k.autotune(v, v, cache=cache, repeats=1, warmup=1, backend="pallas")
    rx = k.autotune(v, v, cache=cache, repeats=1, warmup=1, backend="xla")
    nb = dispatch.n_bucket(50_000)
    assert k._tuned[("pallas", nb)] == rp.best["block_rows"]
    assert k._tuned[("xla", nb)] == rx.best["block_rows"]
    # the tuning-cache keys differ per backend: the second tune must not
    # be a cache hit of the first
    assert not rx.cached


# ------------------------------------------------------ numerics parity
def test_xla_elementwise_matches_pallas_multi_statement():
    k = ElementwiseKernel(
        "float *x, float *y, float *z, float *w",
        "float t = x[i] * y[i]; z[i] = t + expf(-fabsf(t)); w[i] = z[i] * 0.5f",
        name="bk_multi")
    x = jnp.asarray(rng.standard_normal(3000).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(3000).astype(np.float32))
    zp, wp = k(x, y, x, y, backend="pallas")
    zx, wx = k(x, y, x, y, backend="xla")
    np.testing.assert_allclose(np.asarray(zp), np.asarray(zx), atol=1e-6)
    np.testing.assert_allclose(np.asarray(wp), np.asarray(wx), atol=1e-6)


@pytest.mark.parametrize("n", (127, 128, 4097))
def test_xla_reduction_matches_pallas_multi_acc(n):
    stats = ReductionKernel(
        [np.float32] * 3, ["3.4e38", "-3.4e38", "0"],
        ["fminf(a,b)", "fmaxf(a,b)", "a+b"],
        ["x[i]", "x[i]", "x[i]"], "float *x", name="bk_stats")
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    got_p = [float(v) for v in stats(x, backend="pallas")]
    got_x = [float(v) for v in stats(x, backend="xla")]
    ref = [float(np.min(np.asarray(x))), float(np.max(np.asarray(x))),
           float(np.sum(np.asarray(x)))]
    np.testing.assert_allclose(got_p, ref, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(got_x, ref, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("B,n", [(1, 513), (5, 1024)])
def test_xla_row_reduction_matches_pallas(B, n):
    rowsum = ReductionKernel(np.float32, "0", "a+b", "x[i]", "float *x",
                             name="bk_rowsum", axis=-1)
    x = jnp.asarray(rng.standard_normal((B, n)).astype(np.float32))
    got_p = np.asarray(rowsum(x, backend="pallas"))
    got_x = np.asarray(rowsum(x, backend="xla"))
    ref = np.asarray(x).sum(-1)
    np.testing.assert_allclose(got_p, ref, atol=1e-3)
    np.testing.assert_allclose(got_x, ref, atol=1e-3)


@pytest.mark.parametrize("expr,ref_fn", [
    ("a+b", lambda v: np.cumsum(v)),
    ("fmaxf(a,b)", lambda v: np.maximum.accumulate(v)),
])
def test_xla_scan_matches_pallas(expr, ref_fn):
    k = InclusiveScanKernel(np.float32, expr, name=f"bk_scan_{expr[:4]}")
    x = jnp.asarray(rng.standard_normal(10_000).astype(np.float32))
    got_p = np.asarray(k(x, backend="pallas"))
    got_x = np.asarray(k(x, backend="xla"))
    ref = ref_fn(np.asarray(x))
    np.testing.assert_allclose(got_p, ref, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(got_x, ref, rtol=1e-4, atol=1e-3)


def test_xla_exclusive_scan_matches_pallas():
    k = ExclusiveScanKernel(np.float32, "a+b", "0", name="bk_exscan")
    x = jnp.asarray(rng.standard_normal(5000).astype(np.float32))
    got_p = np.asarray(k(x, backend="pallas"))
    got_x = np.asarray(k(x, backend="xla"))
    ref = np.concatenate([[0.0], np.cumsum(np.asarray(x))[:-1]])
    np.testing.assert_allclose(got_p, ref, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(got_x, ref, rtol=1e-4, atol=1e-3)


# -------------------------------------------------- planner pass-through
def test_planner_backend_pin_identical_schedule():
    """A pinned backend runs the exact same 2-launch schedule: one row
    wave + one epilogue, every launch tagged with the pinned backend."""
    x = rng.standard_normal((4, 700)).astype(np.float32)
    ref = np.asarray(jax.nn.softmax(jnp.asarray(x), axis=-1))
    outs = {}
    for be in ("pallas", "xla"):
        sm = ga.softmax(ga.RTCGArray(jnp.asarray(x)), stable=True)
        with dispatch.count_launches() as c:
            outs[be] = np.asarray(sm.evaluate(backend=be).value)
        assert c.delta == 2 and c.by_backend == {be: 2}
        np.testing.assert_allclose(outs[be], ref, atol=1e-5)
    np.testing.assert_allclose(outs["pallas"], outs["xla"], atol=1e-6)


def test_layers_backend_pass_through():
    from repro.models.layers import fused_softmax, rtcg_rmsnorm

    x = jnp.asarray(rng.standard_normal((3, 257)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal(257).astype(np.float32))
    sm_ref = np.asarray(jax.nn.softmax(x, axis=-1))
    rm_ref = (np.asarray(x) / np.sqrt((np.asarray(x) ** 2).mean(-1, keepdims=True)
                                      + 1e-6) * np.asarray(w))
    for be in ("pallas", "xla"):
        with dispatch.count_launches() as c:
            sm = fused_softmax(x, backend=be)
        assert c.by_backend == {be: 2}
        np.testing.assert_allclose(np.asarray(sm), sm_ref, atol=1e-5)
        with dispatch.count_launches() as c:
            rm = rtcg_rmsnorm(x, w, backend=be)
        assert c.by_backend == {be: 2}
        np.testing.assert_allclose(np.asarray(rm), rm_ref, atol=1e-4)


def test_env_selection_routes_generated_kernels(monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "xla")
    k = ElementwiseKernel("float *z, float *x", "z[i] = x[i] + 1",
                          name="bk_env_probe")
    x = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    with dispatch.count_launches() as c:
        k(x, x)
    assert c.by_backend == {"xla": 1}
    # explicit arg overrides the env selection
    with dispatch.count_launches() as c:
        k(x, x, backend="pallas")
    assert c.by_backend == {"pallas": 1}


def test_pinned_and_env_plans_share_kernel_and_tuning(monkeypatch):
    """A plan pinned to backend="xla" and a backend=None plan evaluated
    under REPRO_BACKEND=xla must resolve the SAME kernel instance, so
    tuning winners recorded through either route apply to both."""
    x = ga.to_gpu(np.asarray(rng.standard_normal(3000), np.float32))
    ga.autotune((2 * x + 1).sum(), backend="xla", repeats=1, warmup=1)
    monkeypatch.setenv("REPRO_BACKEND", "xla")
    k_pin = ga.plan_many([(2 * x + 1).sum()], backend="xla").steps[0].kernel()
    k_env = ga.plan_many([(2 * x + 1).sum()]).steps[0].kernel()
    assert k_pin is k_env
    assert ("xla", dispatch.n_bucket(3000)) in k_env._tuned


def test_block_insensitive_backend_shares_driver_across_block_rows():
    """block_rows does not change the xla-generated code, so tuning
    candidates that pad to the same bucket share ONE compiled driver
    (pallas, whose BlockSpecs depend on it, compiles per block size)."""
    k = ElementwiseKernel("float *o, float *v", "o[i] = v[i] * 4",
                          name="bk_blockshare")
    v = jnp.asarray(rng.standard_normal(64 * 128).astype(np.float32))
    cx0 = dispatch.compile_count("xla")
    k(v, v, backend="xla", block_rows=8)
    k(v, v, backend="xla", block_rows=16)
    assert dispatch.compile_count("xla") == cx0 + 1
    cp0 = dispatch.compile_count("pallas")
    k(v, v, backend="pallas", block_rows=8)
    k(v, v, backend="pallas", block_rows=16)
    assert dispatch.compile_count("pallas") == cp0 + 2


def test_xla_backend_renders_source_without_pallas():
    """The xla lowering of an elementwise spec is plain jnp source — no
    refs, no program_id, no pallas import needed to execute it."""
    k = ElementwiseKernel("float *z, float *x", "z[i] = 2*x[i]",
                          name="bk_render_probe")
    src = k.render(8, backend="xla")
    assert "pl." not in src and "_ref" not in src
    psrc = k.render(8, backend="pallas")
    assert "pl.program_id" in psrc or "_ref" in psrc
