"""Supervised serving fleet (PR 8): supervisor state machines, worker
process fault tolerance, overload shed, and crash-safe warm restart.

The policy classes (`BackoffPolicy`, `CrashLoopBreaker`) are tested as
pure state machines on an injected clock; the process-level behaviours
(kill → re-dispatch, hang → heartbeat kill, crash-loop → breaker open,
rolling restart → zero-compile warm-up) run real ``spawn`` workers with
deterministic ``worker.*`` fault rules."""

import time

import numpy as np
import pytest

from repro.runtime.fleet import FleetOverloadError, ServingFleet
from repro.runtime.supervisor import BackoffPolicy, CrashLoopBreaker

BACKENDS = ["xla", "pallas"]


def _fleet(tmp_path, **kw):
    kw.setdefault("workers", 2)
    kw.setdefault("backend", "xla")
    kw.setdefault("max_batch", 8)
    kw.setdefault("cache_dir", str(tmp_path / "fleet-cache"))
    kw.setdefault("supervisor_tick", 0.05)
    return ServingFleet(**kw)


def _rows(k=6, n=64, seed=0):
    return np.random.default_rng(seed).standard_normal((k, n)).astype(
        np.float32)


# ---------------------------------------------------------------------------
# policy state machines (no processes, injected clock)
# ---------------------------------------------------------------------------

class TestBackoffPolicy:
    def test_schedule_doubles_to_cap(self):
        p = BackoffPolicy(base=0.05, cap=2.0)
        assert p.schedule(7) == [0.05, 0.1, 0.2, 0.4, 0.8, 1.6, 2.0]
        assert p.delay(100) == 2.0
        assert p.delay(0) == 0.0

    def test_rejects_bad_params(self):
        with pytest.raises(ValueError):
            BackoffPolicy(base=0.0)
        with pytest.raises(ValueError):
            BackoffPolicy(base=1.0, cap=0.5)


class TestCrashLoopBreaker:
    def make(self):
        return CrashLoopBreaker(threshold=3, min_uptime=1.0, cooldown=5.0)

    def test_opens_after_k_rapid_deaths(self):
        b = self.make()
        t = 0.0
        for i in range(2):
            b.record_start(t)
            opened = b.record_death(t + 0.1)  # rapid: uptime < 1.0
            assert opened is False and b.state == "closed"
            assert b.allow_restart(t + 0.2)
            t += 0.2
        b.record_start(t)
        assert b.record_death(t + 0.1) is True  # the 3rd rapid death opens
        assert b.state == "open"
        assert not b.allow_restart(t + 1.0)

    def test_slow_death_resets_rapid_run(self):
        b = self.make()
        for t in (0.0, 10.0):
            b.record_start(t)
            b.record_death(t + 0.1)
        b.record_start(20.0)
        assert b.record_death(25.0) is False  # healthy uptime: run broken
        assert b.rapid_deaths == 0 and b.state == "closed"

    def _drive_open(self, b, t0=0.0):
        t = t0
        for _ in range(b.threshold):
            b.record_start(t)
            b.record_death(t + 0.1)
            t += 0.2
        assert b.state == "open"
        return t

    def test_cooldown_admits_one_halfopen_probe(self):
        b = self.make()
        t = self._drive_open(b)
        assert not b.allow_restart(t + 1.0)       # inside cooldown
        assert b.allow_restart(t + 5.1)           # cooldown over: the probe
        assert b.state == "half_open"
        assert not b.allow_restart(t + 5.2)       # only ONE probe

    def test_probe_recovery_closes(self):
        b = self.make()
        t = self._drive_open(b)
        assert b.allow_restart(t + 5.1)
        b.record_start(t + 5.1)
        b.note_healthy(t + 7.0)
        assert b.state == "closed" and b.rapid_deaths == 0
        assert b.allow_restart(t + 7.1)

    def test_probe_rapid_death_reopens(self):
        b = self.make()
        t = self._drive_open(b)
        assert b.allow_restart(t + 5.1)
        b.record_start(t + 5.1)
        assert b.record_death(t + 5.2) is True
        assert b.state == "open"
        assert not b.allow_restart(t + 6.0)


# ---------------------------------------------------------------------------
# overload control (no worker processes needed: start=False)
# ---------------------------------------------------------------------------

def test_admission_queue_sheds_overflow(tmp_path):
    fleet = _fleet(tmp_path, workers=1, queue_depth=4, start=False)
    rows = _rows(5)
    futs = [fleet.submit_softmax(rows[i]) for i in range(4)]
    with pytest.raises(FleetOverloadError):
        fleet.submit_softmax(rows[4])
    assert fleet.fleet_stats()["shed"] == 1
    fleet.close(timeout=0.5)
    for f in futs:  # shutdown fails queued futures explicitly
        with pytest.raises(RuntimeError, match="fleet closed"):
            f.result(timeout=1)
    with pytest.raises(RuntimeError, match="closed"):
        fleet.submit_softmax(rows[0])


# ---------------------------------------------------------------------------
# live fleets
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fleet_serves_and_merges_stats(tmp_path):
    fleet = _fleet(tmp_path)
    try:
        fleet.wait_ready(timeout=180)
        rows = _rows(10)
        futs = [fleet.submit_softmax(r) for r in rows]
        out = [f.result(timeout=60) for f in futs]
        for o in out:
            assert abs(float(np.sum(o)) - 1.0) < 1e-3
        tok = fleet.submit_sample(rows[0], seed=7).result(timeout=60)
        assert 0 <= int(tok) < rows.shape[1]
        # identical seed => identical draw (hedge/redispatch safety)
        tok2 = fleet.submit_sample(rows[0], seed=7).result(timeout=60)
        assert int(tok) == int(tok2)
        st = fleet.stats()
        assert st["merged"]["workers_merged"] == 2
        assert st["fleet"]["completed"] == st["fleet"]["submitted"]
        assert st["fleet"]["failed"] == 0
        pids = {w.get("pid") for w in st["workers"]}
        assert len(pids) == 2  # genuinely separate processes
    finally:
        fleet.close()


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
def test_worker_kill_redispatches_inflight(tmp_path, backend):
    # every first-incarnation worker dies serving its 2nd group; the
    # supervisor restarts them and the requests finish on survivors /
    # successors within their deadline
    fleet = _fleet(
        tmp_path, backend=backend, max_outstanding=1, max_redispatch=3,
        group_max=1,  # one request per group: the kill lands on group 2
        chaos_rules=[{"site": "worker.kill", "index": 2, "times": 1}],
        chaos_incarnations=[1],
        backoff=BackoffPolicy(base=0.01, cap=0.1))
    try:
        fleet.wait_ready(timeout=180)
        rows = _rows(8)
        futs = [fleet.submit_softmax(r, deadline=120) for r in rows]
        out = [f.result(timeout=120) for f in futs]
        for o in out:
            assert abs(float(np.sum(o)) - 1.0) < 1e-3
        st = fleet.fleet_stats()
        assert st["deaths"].get("crash", 0) >= 1
        assert st["redispatched"] >= 1
        assert st["completed"] == len(rows)
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:  # supervisor restarts them
            if all(s["alive"] and s["ready"]
                   for s in fleet.fleet_stats()["slots"]):
                break
            time.sleep(0.1)
        assert all(s["alive"] for s in fleet.fleet_stats()["slots"])
    finally:
        fleet.close()


@pytest.mark.slow
def test_worker_hang_detected_via_heartbeat(tmp_path):
    # first group wedges the handler: heartbeats stop, the supervisor
    # kills the silent worker and the request re-dispatches
    fleet = _fleet(
        tmp_path, hb_interval=0.1, hb_timeout=1.0, max_redispatch=3,
        chaos_rules=[{"site": "worker.hang", "index": 1, "times": 1}],
        chaos_incarnations=[1],
        backoff=BackoffPolicy(base=0.01, cap=0.1))
    try:
        fleet.wait_ready(timeout=180)
        fut = fleet.submit_softmax(_rows(1)[0], deadline=120)
        out = fut.result(timeout=120)
        assert abs(float(np.sum(out)) - 1.0) < 1e-3
        st = fleet.fleet_stats()
        assert st["deaths"].get("hang", 0) >= 1
        assert st["redispatched"] >= 1
    finally:
        fleet.close()


@pytest.mark.slow
def test_startup_crash_loop_opens_breaker(tmp_path):
    # every incarnation dies at the startup probe (index=0): after
    # `threshold` rapid deaths the slot's breaker opens and restarts stop
    fleet = _fleet(
        tmp_path, workers=1, warmup=False,
        chaos_rules=[{"site": "worker.kill", "index": 0}],
        backoff=BackoffPolicy(base=0.01, cap=0.05),
        breaker_factory=lambda: CrashLoopBreaker(
            threshold=3, min_uptime=30.0, cooldown=300.0))
    try:
        deadline = time.monotonic() + 120
        while time.monotonic() < deadline:
            slot = fleet.fleet_stats()["slots"][0]
            if slot["breaker"]["state"] == "open":
                break
            time.sleep(0.1)
        st = fleet.fleet_stats()
        slot = st["slots"][0]
        assert slot["breaker"]["state"] == "open"
        assert slot["breaker"]["total_deaths"] >= 3
        assert st["starts"] >= 3
        starts_at_open = st["starts"]
        time.sleep(0.5)  # breaker open: no further restart attempts
        assert fleet.fleet_stats()["starts"] == starts_at_open
    finally:
        fleet.close(timeout=5)


@pytest.mark.slow
def test_worker_reject_isolates_and_retries(tmp_path):
    # a sick-but-responsive worker error-replies its 1st group: requests
    # re-dispatch (solo) and succeed without any process death
    fleet = _fleet(
        tmp_path, max_redispatch=3,
        chaos_rules=[{"site": "worker.reject", "index": 1, "times": 1}],
        chaos_incarnations=[1])
    try:
        fleet.wait_ready(timeout=180)
        rows = _rows(4)
        futs = [fleet.submit_softmax(r, deadline=120) for r in rows]
        for f in futs:
            assert abs(float(np.sum(f.result(timeout=120))) - 1.0) < 1e-3
        st = fleet.fleet_stats()
        assert st["redispatched"] >= 1
        assert not st["deaths"]
    finally:
        fleet.close()


@pytest.mark.slow
def test_hedging_duplicates_stragglers_harmlessly(tmp_path):
    # a worker.slow straggler trips the hedge timer; the duplicate
    # completion is absorbed by first-writer-wins futures
    fleet = _fleet(
        tmp_path, hedge_after=0.25, max_outstanding=4,
        chaos_rules=[{"site": "worker.slow", "index": 1, "times": 1}],
        chaos_incarnations=[1],
        env={"REPRO_CHAOS_SLOW_S": "2.0"})
    try:
        fleet.wait_ready(timeout=180)
        fut = fleet.submit_softmax(_rows(1)[0])
        out = fut.result(timeout=120)
        assert abs(float(np.sum(out)) - 1.0) < 1e-3
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline and \
                fleet.fleet_stats()["hedges"] < 1:
            time.sleep(0.05)
        st = fleet.fleet_stats()
        assert st["hedges"] >= 1
        assert st["failed"] == 0
        # both slow rules are spent: traffic is fast and exactly-once now
        t0 = time.monotonic()
        fleet.submit_softmax(_rows(1, seed=1)[0]).result(timeout=60)
        assert time.monotonic() - t0 < 1.5
    finally:
        fleet.close()


@pytest.mark.slow
def test_graceful_drain_and_rolling_restart_warm(tmp_path):
    # rolling restart rotates every slot with zero crashes; the fresh
    # incarnations warm from the shared manifest and serve the same
    # traffic with ZERO compiles (the crash-safe warm-restart claim)
    fleet = _fleet(tmp_path, max_redispatch=2)
    try:
        fleet.wait_ready(timeout=180)
        rows = _rows(8)
        futs = [fleet.submit_softmax(r) for r in rows]
        futs += [fleet.submit_rmsnorm(rows[0], np.ones(64, np.float32))]
        [f.result(timeout=60) for f in futs]
        fleet.drain(timeout=60)
        fleet.sync_workers()
        rep = fleet.rolling_restart(wait_timeout=180)
        assert rep["rotated"] == 2
        assert rep["incarnations"] == [2, 2]
        futs = [fleet.submit_softmax(r) for r in rows]
        futs += [fleet.submit_rmsnorm(rows[0], np.ones(64, np.float32))]
        [f.result(timeout=60) for f in futs]
        st = fleet.stats()
        assert not st["fleet"]["deaths"], "rolling restart must not crash"
        compiles = [w.get("serving_compiles") for w in st["workers"]]
        assert compiles and all(c == 0 for c in compiles), \
            f"restarted workers must serve compile-free, got {compiles}"
        assert st["fleet"]["failed"] == 0
    finally:
        fleet.close()
