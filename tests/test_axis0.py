"""axis=0 column reductions (kernel IR `transpose_layout`, DESIGN.md §11).

Covers: ``.sum/.max/.mean(axis=0)`` over 2-D operands through the lazy
planner on BOTH backends with exact launch counts, parity sweeps across
batch sizes x bucket-boundary row lengths, axis=0 softmax staying the
2-launch wave+epilogue schedule (stable included), the ``transposed``
bucket key separating axis=0 winners from axis=-1 winners, mixed
axis=0/axis=-1 graphs scheduling into separate waves, and the serving
runtime's ``softmax(..., axis=0)`` family.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.core.array as ga
from repro.core import dispatch

rng = np.random.default_rng(11)

BOUNDARY_NS = (1023, 1024, 1025)
BATCHES = (1, 7, 32)


@pytest.fixture(scope="module", params=["pallas", "xla"], autouse=True)
def rtcg_backend(request):
    """Column reductions are a layout transformation on the SAME IR both
    backends render — every parity/launch assertion must hold on pallas
    and xla alike."""
    import os

    old = os.environ.get("REPRO_BACKEND")
    os.environ["REPRO_BACKEND"] = request.param
    yield request.param
    if old is None:
        os.environ.pop("REPRO_BACKEND", None)
    else:
        os.environ["REPRO_BACKEND"] = old


def _launches(fn):
    with dispatch.count_launches() as c:
        out = fn()
    return out, c.delta


# -------------------------------------------------- parity + launches
@pytest.mark.parametrize("B", BATCHES)
@pytest.mark.parametrize("n", BOUNDARY_NS)
def test_col_reduce_shapes_and_values(B, n):
    """sum/max over axis=0: one launch each, (N,)-shaped, numpy parity.
    The domain is transposed (N independent outputs reduce over B), the
    storage is not — `transpose_layout` bridges the two at bind time."""
    x = rng.standard_normal((B, n)).astype(np.float32)
    X = ga.to_gpu(x)
    s = X.sum(axis=0)
    assert s.shape == (n,)
    got, delta = _launches(lambda: s.value)
    assert delta == 1
    np.testing.assert_allclose(np.asarray(got), x.sum(0), atol=1e-2)
    mx, delta = _launches(lambda: X.max(axis=0).value)
    assert delta == 1
    np.testing.assert_allclose(np.asarray(mx), x.max(0), rtol=1e-6)


@pytest.mark.parametrize("B", BATCHES)
@pytest.mark.parametrize("n", BOUNDARY_NS)
def test_col_mean_parity(B, n):
    x = rng.standard_normal((B, n)).astype(np.float32)
    m, delta = _launches(lambda: ga.to_gpu(x).mean(axis=0).value)
    assert delta == 1
    np.testing.assert_allclose(np.asarray(m), x.mean(0), atol=1e-3)


def test_axis_minus_two_aliases_axis0():
    x = rng.standard_normal((5, 33)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(ga.to_gpu(x).sum(axis=-2).value), x.sum(0), atol=1e-3)


@pytest.mark.parametrize("stable", [False, True])
@pytest.mark.parametrize("B", BATCHES)
@pytest.mark.parametrize("n", BOUNDARY_NS)
def test_axis0_softmax_exactly_two_launches(B, n, stable):
    """Softmax over columns keeps the acceptance schedule: ONE column
    wave (max + shifted-exp sum chained in-kernel when stable) + ONE
    fused epilogue."""
    x = (rng.standard_normal((B, n)) * 4).astype(np.float32)
    ref = np.asarray(jax.nn.softmax(jnp.asarray(x), axis=0))
    sm, delta = _launches(
        lambda: ga.softmax(ga.to_gpu(x), stable=stable, axis=0).value)
    assert delta == 2
    np.testing.assert_allclose(np.asarray(sm), ref, atol=1e-5)


def test_axis0_epilogue_broadcast_orientation():
    """An axis=0 reduce consumed by a 2-D epilogue binds as a per-COLUMN
    broadcast: x - x.mean(axis=0) must center every column."""
    x = rng.standard_normal((9, 257)).astype(np.float32)
    X = ga.to_gpu(x)
    out, delta = _launches(lambda: (X - X.mean(axis=0)).value)
    assert delta == 2
    np.testing.assert_allclose(np.asarray(out), x - x.mean(0), atol=1e-3)


def test_mixed_axes_schedule_separate_waves():
    """axis=-1 and axis=0 reduces over the same operand cannot share a
    wave (different domains): planned together they cost one wave EACH,
    and both roots still evaluate correctly."""
    x = rng.standard_normal((8, 64)).astype(np.float32)
    X = ga.to_gpu(x)
    rowsum, colsum = X.sum(axis=-1), X.sum(axis=0)
    sched = ga.plan_many([rowsum, colsum])
    assert len(sched.steps) == 2
    (r, delta_r) = _launches(lambda: rowsum.value)
    (c, delta_c) = _launches(lambda: colsum.value)
    assert delta_r == 1 and delta_c == 1
    np.testing.assert_allclose(np.asarray(r), x.sum(-1), atol=1e-2)
    np.testing.assert_allclose(np.asarray(c), x.sum(0), atol=1e-2)


# ------------------------------------------------------ bucket identity
def test_transposed_bucket_key_never_collides():
    """Satellite 6: the dispatch bucket for a transposed (axis=0) domain
    carries a layout marker, so an axis=0 winner tuned at (b, n) can
    never be replayed onto the axis=-1 kernel of the same geometry."""
    for b, n in [(8, 1024), (32, 1023), (1, 7)]:
        plain = dispatch.rc_bucket(b, n)
        transposed = dispatch.rc_bucket(b, n, transposed=True)
        assert transposed != plain
        assert transposed[:2] == plain
        assert dispatch.rc_bucket(b, n, transposed=True) == transposed


def test_axis0_driver_reuse_within_bucket():
    """Two different (B, N) geometries sharing a bucket pair share the
    axis=0 driver — the second evaluation compiles nothing."""
    a = rng.standard_normal((10, 900)).astype(np.float32)
    b = rng.standard_normal((12, 1000)).astype(np.float32)
    ga.to_gpu(a).sum(axis=0).value  # warm the bucket
    with dispatch.count_compiles() as cc:
        got = ga.to_gpu(b).sum(axis=0).value
    assert cc.delta == 0
    np.testing.assert_allclose(np.asarray(got), b.sum(0), atol=1e-2)


# ------------------------------------------------------ serving runtime
def test_runtime_softmax_axis0(rtcg_backend, tmp_path):
    from repro.core.cache import DiskCache
    from repro.runtime import ServingRuntime
    from repro.runtime.manifest import WarmStartManifest

    manifest = WarmStartManifest(cache=DiskCache("runtime_manifest",
                                                 root=tmp_path))
    rt = ServingRuntime(backend=rtcg_backend, manifest=manifest)
    x = rng.standard_normal((6, 40)).astype(np.float32)
    got = rt.softmax(x, axis=0)
    ref = np.asarray(jax.nn.softmax(jnp.asarray(x), axis=0))
    np.testing.assert_allclose(np.asarray(got), ref, atol=1e-5)
    with pytest.raises(ValueError):
        rt.softmax(np.zeros((2, 3, 4), np.float32), axis=0)
