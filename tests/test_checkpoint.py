"""Checkpoint/restore, elastic resharding, resume determinism."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import manager as ckpt


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (33, 17)),
            "b": {"w": jax.random.normal(k2, (8,)).astype(jnp.bfloat16),
                  "step": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    ckpt.save(tmp_path, 5, tree, extras={"note": "x"})
    assert ckpt.latest_step(tmp_path) == 5
    restored, extras = ckpt.restore(tmp_path, 5, tree)
    assert extras == {"note": "x"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()  # bit-exact


def test_bf16_exact_roundtrip(tmp_path):
    tree = {"w": (jnp.arange(100, dtype=jnp.float32) / 7).astype(jnp.bfloat16)}
    ckpt.save(tmp_path, 1, tree)
    restored, _ = ckpt.restore(tmp_path, 1, tree)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(tree["w"]).view(np.uint16),
                                  np.asarray(restored["w"]).view(np.uint16))


def test_retention_keeps_latest(tmp_path):
    tree = {"w": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, tree, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [4, 5]


def test_structure_mismatch_rejected(tmp_path):
    ckpt.save(tmp_path, 1, {"w": jnp.zeros(3)})
    with pytest.raises(AssertionError):
        ckpt.restore(tmp_path, 1, {"w": jnp.zeros(3), "extra": jnp.zeros(1)})


def test_elastic_reshard_across_meshes(tmp_path, subproc):
    """Save on a (4,2) mesh, restore onto (2,2,2) and (8,1): values must
    be identical regardless of mesh topology."""
    out = subproc(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import manager as ckpt
from repro.launch.mesh import make_mesh

mesh_a = make_mesh((4, 2), ("data", "model"))
w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
tree = {{"w": jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))}}
ckpt.save(r"{tmp_path}", 3, tree)

for shape, names, spec in [((2, 2, 2), ("pod", "data", "model"), P(("pod", "data"), "model")),
                           ((8, 1), ("data", "model"), P("data", None))]:
    mesh_b = make_mesh(shape, names)
    tgt = {{"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}}
    restored, _ = ckpt.restore(r"{tmp_path}", 3, tgt,
                               shardings={{"w": NamedSharding(mesh_b, spec)}})
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


def test_train_resume_bit_exact(tmp_path):
    """20 straight steps == 10 steps + checkpoint + resume + 10 steps."""
    from repro.launch import train as train_mod
    base = ["--arch", "internlm2-1.8b", "--smoke", "--batch", "4",
            "--seq", "32", "--log-every", "100"]
    loss_straight = train_mod.main(base + ["--steps", "20"])
    ck = str(tmp_path / "ck")
    train_mod.main(base + ["--steps", "10", "--ckpt-dir", ck, "--ckpt-every", "10"])
    loss_resumed = train_mod.main(base + ["--steps", "20", "--ckpt-dir", ck,
                                          "--resume", "--ckpt-every", "100"])
    assert loss_straight == pytest.approx(loss_resumed, rel=1e-5)
