"""PR 6 fault-tolerance acceptance: fault injection, circuit breaker,
backend failover, degradation ladder, poison-row isolation, crash-safe
caches (DESIGN.md §10)."""

import json
import threading
import time
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.array as ga
from repro import runtime as rtm
from repro.core import dispatch
from repro.core.cache import DiskCache
from repro.models.layers import fused_softmax, rtcg_rmsnorm
from repro.runtime.faults import (FaultPlan, FaultRule, InjectedFault,
                                  maybe_fail)
from repro.runtime.manifest import WarmStartManifest
from repro.runtime.router import (BackendRouter, CircuitBreaker,
                                  set_default_breaker)

BACKENDS = ("pallas", "xla")


@pytest.fixture(autouse=True)
def _isolated_breaker():
    """Each test gets a pristine process-wide breaker and a clean
    one-time-warning slate; the default is restored afterwards."""
    set_default_breaker(CircuitBreaker())
    ga._failover_warned.clear()
    yield
    set_default_breaker(None)


@pytest.fixture(autouse=True)
def _no_ambient_plans():
    """These tests assert exact injection behavior of their OWN plans;
    suspend any ambient plan (the CI chaos leg's REPRO_CHAOS env plan)
    for the duration and restore it afterwards."""
    from repro.runtime import faults

    ambient = faults.active_plans()
    for p in ambient:
        p.deactivate()
    yield
    for p in ambient:
        p.activate()


def _fresh_runtime(tmp_path, K=8, backend="pallas", window=0.25):
    man = WarmStartManifest(
        cache=DiskCache("runtime_manifest", root=Path(tmp_path)))
    return rtm.ServingRuntime(backend=backend, window=window, max_batch=K,
                              router=BackendRouter(), manifest=man)


def _rows(K=8, N=512, seed=0):
    rng = np.random.RandomState(seed)
    return [jnp.asarray(rng.randn(N).astype(np.float32)) for _ in range(K)]


# --------------------------------------------------------------- FaultPlan
def test_count_rule_fires_deterministically():
    with FaultPlan([FaultRule(site="launch", count=2)]) as plan:
        for _ in range(2):
            with pytest.raises(InjectedFault):
                maybe_fail("launch", backend="pallas")
        maybe_fail("launch", backend="pallas")  # exhausted: silent
    assert plan.stats()["injected"] == {"launch": 2}


def test_probability_rule_is_seeded():
    def pattern(seed):
        fires = []
        with FaultPlan([FaultRule(site="launch", probability=0.5)],
                       seed=seed):
            for _ in range(64):
                try:
                    maybe_fail("launch")
                    fires.append(0)
                except InjectedFault:
                    fires.append(1)
        return fires

    assert pattern(7) == pattern(7)
    assert pattern(7) != pattern(8)
    assert 10 < sum(pattern(7)) < 54


def test_rule_matching_narrows():
    rule = FaultRule(site="launch", backend="pallas", family="softmax",
                     index=3)
    with FaultPlan([rule]):
        maybe_fail("launch", backend="xla", family="softmax", index=3)
        maybe_fail("launch", backend="pallas", family="rmsnorm", index=3)
        maybe_fail("launch", backend="pallas", family="softmax", index=4)
        maybe_fail("compile", backend="pallas", family="softmax", index=3)
        with pytest.raises(InjectedFault):
            maybe_fail("launch", backend="pallas", family="fused_softmax_x",
                       index=3)  # family matches as substring


def test_faults_never_leak_outside_plan():
    with FaultPlan([FaultRule(site="launch")]):
        with pytest.raises(InjectedFault):
            maybe_fail("launch")
    maybe_fail("launch")  # no active plan: the probe is inert
    x = jnp.asarray(np.random.RandomState(0).randn(4, 256).astype("f4"))
    d0 = dispatch.degradation_total()
    out = ga.softmax(ga.RTCGArray(x), stable=True).evaluate(
        backend="pallas").value
    np.testing.assert_allclose(out, jax.nn.softmax(x, axis=-1), atol=1e-5)
    assert dispatch.degradation_total() == d0


def test_env_spec_parsing():
    plan = FaultPlan.from_spec("compile:0.05,launch@pallas:1.0")
    assert [(r.site, r.backend, r.probability, r.transient)
            for r in plan.rules] == [("compile", None, 0.05, True),
                                     ("launch", "pallas", 1.0, True)]
    with pytest.raises(ValueError):
        FaultPlan.from_spec("warp:0.1")


def test_transient_faults_absorbed_with_exact_counts():
    """The CI chaos contract: probabilistic transient compile/launch
    faults are retried away inside dispatch, so launch-count assertions
    (and results) are unchanged and no degradation is recorded."""
    x = jnp.asarray(np.random.RandomState(1).randn(8, 512).astype("f4"))
    ref = jax.nn.softmax(x, axis=-1)
    d0 = dispatch.degradation_total()
    with FaultPlan([FaultRule(site="launch", probability=0.2,
                              transient=True),
                    FaultRule(site="compile", probability=0.2,
                              transient=True)], seed=3):
        for _ in range(10):
            with dispatch.count_launches() as c:
                out = ga.softmax(ga.RTCGArray(x), stable=True).evaluate(
                    backend="pallas").value
            assert c.delta == 2
            np.testing.assert_allclose(out, ref, atol=1e-5)
    assert dispatch.degradation_total() == d0


# --------------------------------------------------------- CircuitBreaker
def test_breaker_state_machine():
    b = CircuitBreaker(threshold=3, cooldown=0.15)
    cell = ("softmax", "pallas", (8, 4))
    assert b.state(*cell) == "closed" and not b.active()
    b.record_failure(*cell)
    b.record_failure(*cell)
    assert b.state(*cell) == "closed" and b.active() and not b.any_open()
    b.record_failure(*cell)  # threshold: open
    assert b.state(*cell) == "open"
    assert not b.available(*cell) and b.any_open()
    time.sleep(0.17)
    assert b.state(*cell) == "half-open"  # cooldown elapsed: probe allowed
    assert b.available(*cell)


def test_breaker_probe_failure_reopens_success_closes():
    b = CircuitBreaker(threshold=1, cooldown=0.1)
    cell = ("softmax", "xla", (8, 4))
    b.record_failure(*cell)
    assert b.state(*cell) == "open"
    time.sleep(0.12)
    assert b.state(*cell) == "half-open"
    b.record_failure(*cell)  # failed probe: cooldown restarts
    assert b.state(*cell) == "open"
    time.sleep(0.12)
    b.record_success(*cell)  # successful probe: pristine closed
    assert b.state(*cell) == "closed" and not b.any_open()
    assert b.stats()["open_cells"] == {}


def test_breaker_success_resets_consecutive_count():
    b = CircuitBreaker(threshold=3, cooldown=60.0)
    cell = ("f", "pallas", (1,))
    for _ in range(2):
        b.record_failure(*cell)
    b.record_success(*cell)  # streak broken
    for _ in range(2):
        b.record_failure(*cell)
    assert b.state(*cell) == "closed"  # 2 + 2 non-consecutive never opens


@pytest.mark.parametrize("broken", BACKENDS)
def test_router_routes_around_open_cell(broken):
    other = "xla" if broken == "pallas" else "pallas"
    b = CircuitBreaker(threshold=1, cooldown=60.0)
    r = BackendRouter(breaker=b)
    bucket = (8, 4)
    # give both cells observations so choose() exploits, not explores
    for be in BACKENDS:
        r.observe("softmax", be, bucket, 0.001 if be == broken else 0.002)
    assert r.choose("softmax", bucket) == broken  # EMA winner pre-failure
    b.record_failure("softmax", broken, bucket)
    for _ in range(8):
        assert r.choose("softmax", bucket) == other
    # every cell open: the router still serves (EMA winner)
    b.record_failure("softmax", other, bucket)
    assert r.choose("softmax", bucket) in BACKENDS


# ------------------------------------------------------ degradation ladder
def test_ladder_unfused_rung_counts_and_is_correct():
    x = jnp.asarray(np.random.RandomState(2).randn(8, 384).astype("f4"))
    ref = jax.nn.softmax(x, axis=-1)
    before = dispatch.degradation_counts().get("unfused", 0)
    # exactly one persistent launch failure: the fused wave dies once,
    # the per-kernel rebuild (rule exhausted) succeeds on the same backend
    with FaultPlan([FaultRule(site="launch", backend="pallas", count=1)]):
        out = ga.softmax(ga.RTCGArray(x), stable=True).evaluate(
            backend="pallas").value
    np.testing.assert_allclose(out, ref, atol=1e-5)
    assert dispatch.degradation_counts().get("unfused", 0) == before + 1


@pytest.mark.parametrize("broken", BACKENDS)
def test_ladder_pinned_backend_failover(broken):
    other = "xla" if broken == "pallas" else "pallas"
    x = jnp.asarray(np.random.RandomState(3).randn(4, 320).astype("f4"))
    ref = jax.nn.softmax(x, axis=-1)
    before = dispatch.degradation_counts().get("backend_failover", 0)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with FaultPlan([FaultRule(site="launch", backend=broken),
                        FaultRule(site="compile", backend=broken)]):
            out = fused_softmax(x, backend=broken)
            out2 = fused_softmax(x, backend=broken)  # warning only once
    np.testing.assert_allclose(out, ref, atol=1e-5)
    np.testing.assert_allclose(out2, ref, atol=1e-5)
    assert dispatch.degradation_counts().get("backend_failover", 0) \
        >= before + 2
    failover_warnings = [rec for rec in w
                         if f"falling back to {other!r}" in str(rec.message)]
    assert len(failover_warnings) == 1


def test_ladder_eager_floor():
    x = jnp.asarray(np.random.RandomState(4).randn(4, 288).astype("f4"))
    w = jnp.asarray(np.random.RandomState(5).randn(288).astype("f4"))
    before = dispatch.degradation_counts().get("eager", 0)
    with FaultPlan([FaultRule(site="launch"), FaultRule(site="compile")]):
        s = fused_softmax(x, backend="pallas")
        r = rtcg_rmsnorm(x, w, backend="pallas")
    np.testing.assert_allclose(s, jax.nn.softmax(x, axis=-1), atol=1e-5)
    ref_r = (x / jnp.sqrt((x * x).mean(-1, keepdims=True) + 1e-6)) * w
    np.testing.assert_allclose(r, ref_r, atol=1e-4)
    assert dispatch.degradation_counts().get("eager", 0) >= before + 2


def test_planner_contract_errors_still_raise():
    """The ladder handles *execution* failures; structurally invalid
    expressions must keep raising their planner errors."""
    a = ga.RTCGArray(np.random.RandomState(6).randn(2, 4, 64).astype("f4"))
    with pytest.raises(NotImplementedError):
        a.sum(axis=1)  # middle axes are not fusable (only None / -1 / 0)


@pytest.mark.parametrize("broken", BACKENDS)
def test_runtime_survives_fully_disabled_backend(broken, tmp_path):
    """Acceptance: a fully broken backend (compile+launch faults) still
    serves the quickstart softmax/rmsnorm/sampling paths through the
    other backend, with the failovers recorded in runtime.stats()."""
    other = "xla" if broken == "pallas" else "pallas"
    set_default_breaker(CircuitBreaker(threshold=2, cooldown=3600.0))
    rt = _fresh_runtime(tmp_path, backend=broken)
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(4, 256).astype(np.float32))
    w = jnp.asarray(rng.randn(256).astype(np.float32))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        with FaultPlan([FaultRule(site="launch", backend=broken),
                        FaultRule(site="compile", backend=broken)]):
            for _ in range(3):
                s = rt.softmax(x)
            r = rt.rmsnorm(x, w)
            tok = rt.sample(x, jax.random.PRNGKey(0), temperature=1.0)
    np.testing.assert_allclose(s, jax.nn.softmax(x, axis=-1), atol=1e-5)
    ref_r = (x / jnp.sqrt((x * x).mean(-1, keepdims=True) + 1e-6)) * w
    np.testing.assert_allclose(r, ref_r, atol=1e-4)
    assert tok.shape == (4,)
    st = rt.stats()
    degr = st["degradations"]
    assert degr.get("backend_failover", 0) >= 1
    assert degr.get("backend_failover", 0) + degr.get("breaker_skip", 0) >= 4
    assert st["breaker"]["failovers"] >= 1
    # the breaker opened the broken backend's softmax cell
    assert any(f"|{broken}|" in k for k in st["breaker"]["open_cells"])
    rt.close()


# ------------------------------------------------- executor fault handling
def test_poison_row_isolation(tmp_path):
    """K=8 coalesced flush with one injected poison request: the other
    7 complete with correct results, only the poisoned future errors."""
    rt = _fresh_runtime(tmp_path, K=8)
    rows = _rows(K=8)
    futs = [None] * 8
    with FaultPlan([FaultRule(site="executor.row", family="softmax",
                              index=3)]):
        def submit(i):
            futs[i] = rt.submit_softmax(rows[i])

        threads = [threading.Thread(target=submit, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        # seqs are assigned under the executor lock in submit order; the
        # poisoned *request* is whichever thread drew sequence id 3
        results = []
        for f in futs:
            try:
                results.append(("ok", f.result(timeout=120)))
            except InjectedFault as e:
                results.append(("err", e))
    oks = [r for r in results if r[0] == "ok"]
    errs = [r for r in results if r[0] == "err"]
    assert len(oks) == 7 and len(errs) == 1
    st = rt.executor.stats()
    assert st["batch_retries"] == 1
    assert st["isolated_rows"] == 8
    assert st["row_failures"] == 1
    rt.close()


def test_poisoned_rows_results_still_correct(tmp_path):
    rt = _fresh_runtime(tmp_path, K=4)
    rows = _rows(K=4, N=256, seed=8)
    ref = np.asarray(jax.nn.softmax(jnp.stack(rows), axis=-1))
    with FaultPlan([FaultRule(site="executor.row", family="softmax",
                              index=0)]):
        futs = [rt.submit_softmax(r) for r in rows]
        with pytest.raises(InjectedFault):
            futs[0].result(timeout=120)
        for i in (1, 2, 3):
            np.testing.assert_allclose(futs[i].result(timeout=120),
                                       ref[i], atol=1e-5)
    rt.close()


def test_transient_executor_fault_retries_to_success(tmp_path):
    """A row that fails twice then recovers is served by the bounded
    per-row retry loop — no error ever reaches the future."""
    rt = _fresh_runtime(tmp_path, K=2)
    rows = _rows(K=2, N=256, seed=9)
    ref = np.asarray(jax.nn.softmax(jnp.stack(rows), axis=-1))
    with FaultPlan([FaultRule(site="executor.row", family="softmax",
                              index=1, count=2)]):
        futs = [rt.submit_softmax(r) for r in rows]
        for i in (0, 1):
            np.testing.assert_allclose(futs[i].result(timeout=120),
                                       ref[i], atol=1e-5)
    st = rt.executor.stats()
    assert st["row_failures"] == 0 and st["batch_retries"] == 1
    rt.close()


def test_deadline_bounds_retry_budget(tmp_path):
    rt = _fresh_runtime(tmp_path, K=1, window=0.01)
    row = _rows(K=1, N=256, seed=10)[0]
    with FaultPlan([FaultRule(site="executor.row", family="softmax",
                              index=0)]):
        t0 = time.monotonic()
        fut = rt.submit_softmax(row, deadline=0.5)
        with pytest.raises((InjectedFault, TimeoutError)):
            fut.result(timeout=60)
        assert time.monotonic() - t0 < 30.0
    rt.close()


def test_deadline_bounds_total_budget_including_backoff(tmp_path, monkeypatch):
    """The deadline caps the request's TOTAL time — with a huge retry
    allowance and a persistently failing row, the backoff ladder (which
    alone would sleep for seconds) is clipped at the budget, and the
    TimeoutError reports elapsed vs budget."""
    monkeypatch.setenv("REPRO_RETRY_MAX", "200")
    rt = _fresh_runtime(tmp_path, K=1, window=0.01)
    row = _rows(K=1, N=256, seed=13)[0]
    budget = 0.25
    with FaultPlan([FaultRule(site="executor.row", family="softmax")]):
        t0 = time.monotonic()
        fut = rt.submit_softmax(row, deadline=budget)
        with pytest.raises(TimeoutError) as ei:
            fut.result(timeout=60)
        elapsed = time.monotonic() - t0
    # 200 retries x up-to-50ms backoff would be ~10s unbounded; the
    # budget-clipped ladder must stop within the deadline plus slack
    # for the in-flight attempt it cannot preempt
    assert elapsed < budget + 1.0, f"deadline overshot: {elapsed:.2f}s"
    msg = str(ei.value)
    assert "budget" in msg and f"{budget:.3f}" in msg and "elapsed" in msg
    assert "softmax" in msg and "256" in msg
    rt.close()


def test_future_timeout_message_has_context(tmp_path):
    rt = _fresh_runtime(tmp_path, K=4, window=60.0)  # window never expires
    fut = rt.submit_softmax(_rows(K=1, N=333, seed=11)[0])
    with pytest.raises(TimeoutError) as ei:
        fut.result(timeout=0.05)
    assert "softmax" in str(ei.value) and "333" in str(ei.value)
    rt.executor.close(drain=False)
    rt.close()


def test_close_fails_pending_futures(tmp_path):
    rt = _fresh_runtime(tmp_path, K=16, window=60.0)
    futs = [rt.submit_softmax(r) for r in _rows(K=3, N=128, seed=12)]
    rt.executor.close(drain=False)
    for f in futs:
        with pytest.raises(RuntimeError, match="executor closed"):
            f.result(timeout=5)
    with pytest.raises(RuntimeError, match="executor is closed"):
        rt.submit_softmax(_rows(K=1, N=128)[0])


def test_close_with_wedged_worker_fails_inflight(tmp_path):
    """A flush stuck inside a wedged backend: close(timeout=...) gives
    up on the worker and fails the in-flight futures; the worker's late
    completion is dropped (first writer wins)."""
    rt = _fresh_runtime(tmp_path, K=1, window=0.01)
    release = threading.Event()
    real = rt._run_batch

    def wedged(family, X, shared, **kw):
        release.wait(10.0)
        return real(family, X, shared, **kw)

    rt._run_batch = wedged
    fut = rt.submit_softmax(_rows(K=1, N=128, seed=13)[0])
    time.sleep(0.1)  # let the worker pick the batch up
    rt.executor.close(timeout=0.3)
    with pytest.raises(RuntimeError, match="executor closed"):
        fut.result(timeout=5)
    release.set()
    # drain the late worker completely: its (dropped) completion still
    # launches kernels, which must not bleed into a later test's
    # count_launches window
    worker = rt.executor._thread
    if worker is not None:
        worker.join(timeout=60)
    rt.close()


# ------------------------------------------------ crash-safe persistence
def test_diskcache_quarantines_corrupt_entry(tmp_path):
    c = DiskCache("t", root=Path(tmp_path))
    c.put("good", {"v": 1})
    (c.root / "bad.json").write_text('{"v": 1')  # truncated write
    c2 = DiskCache("t", root=Path(tmp_path))  # fresh mem view
    assert c2.get("bad", "missing") == "missing"
    assert not (c2.root / "bad.json").exists()
    assert (c2.root / "bad.corrupt").exists()  # kept for post-mortems
    assert "bad" not in c2
    assert c2.get("good")["v"] == 1
    c2.put("bad", {"v": 2})  # the slot is reusable after quarantine
    assert DiskCache("t", root=Path(tmp_path)).get("bad") == {"v": 2}


def test_diskcache_put_is_atomic(tmp_path):
    c = DiskCache("t", root=Path(tmp_path))
    c.put("k", {"v": "old"})
    with FaultPlan([FaultRule(site="cache.write")]):
        c.put("k", {"v": "new"})  # write fails: disk keeps the old value
    assert c.get("k") == {"v": "new"}  # this process serves from memory
    assert DiskCache("t", root=Path(tmp_path)).get("k") == {"v": "old"}
    assert json.loads((c.root / "k.json").read_text()) == {"v": "old"}


def test_diskcache_read_fault_is_a_miss(tmp_path):
    c = DiskCache("t", root=Path(tmp_path))
    c.put("k", {"v": 1})
    c2 = DiskCache("t", root=Path(tmp_path))
    with FaultPlan([FaultRule(site="cache.read")]):
        assert c2.get("k", "miss") == "miss"
    assert c2.get("k") == {"v": 1}  # healthy again outside the plan


# ------------------------------------------------------ manifest resilience
def test_manifest_warmup_with_corrupt_entry(tmp_path):
    cache = DiskCache("runtime_manifest", root=Path(tmp_path))
    man = WarmStartManifest(cache=cache)
    man.record("softmax", (4, 256), "float32", "pallas", {"stable": True})
    # injected corruption: one malformed entry + one wrong-typed entry
    cache.update("manifest-v1", lambda doc: {
        "entries": {**doc["entries"],
                    "deadbeef": {"family": "softmax", "geometry": "bogus",
                                 "dtype": "float32", "backend": "pallas"},
                    "cafebabe": ["not", "a", "dict"]},
        "observed_keys": doc["observed_keys"]})
    rt = rtm.ServingRuntime(
        backend="pallas", window=0.01, max_batch=4, router=BackendRouter(),
        manifest=WarmStartManifest(cache=cache))
    report = rt.warmup()
    assert report["replayed"] == 1          # the healthy entry warmed
    assert len(report["errors"]) == 1       # the malformed one is reported
    assert report["entries"] == 2           # non-dict entry dropped on load
    rt.close()


def test_manifest_tolerates_wrong_shaped_document(tmp_path):
    cache = DiskCache("runtime_manifest", root=Path(tmp_path))
    cache.put("manifest-v1", ["not", "a", "manifest"])
    man = WarmStartManifest(cache=cache)
    assert len(man) == 0
    assert man.replay(lambda e: None)["entries"] == 0
    man.record("softmax", (2, 128), "float32", "xla", {"stable": True})
    assert WarmStartManifest(cache=cache).entries()[0]["backend"] == "xla"


# ----------------------------------------------------------- observability
def test_runtime_stats_has_fault_sections(tmp_path):
    rt = _fresh_runtime(tmp_path, K=2)
    st = rt.stats()
    assert set(st["breaker"]) >= {"threshold", "cooldown_s", "failovers",
                                  "open_cells"}
    assert isinstance(st["degradations"], dict)
    assert st["faults"]["active_plans"] == 0
    with FaultPlan([FaultRule(site="launch", count=1)]):
        assert rt.stats()["faults"]["active_plans"] == 1
    rt.close()
