"""ScanKernel + curandom tests (the remaining PyCUDA surface)."""

import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core import ExclusiveScanKernel, InclusiveScanKernel
from repro.core import curandom


def test_inclusive_cumsum():
    x = jnp.asarray(np.random.default_rng(0).integers(0, 9, 9001).astype(np.float32))
    k = InclusiveScanKernel(np.float32, "a+b")
    np.testing.assert_allclose(k(x), np.cumsum(np.asarray(x)), rtol=1e-5)


def test_exclusive_cumsum():
    x = jnp.asarray(np.random.default_rng(1).integers(0, 9, 5000).astype(np.float32))
    k = ExclusiveScanKernel(np.float32, "a+b", neutral="0")
    ref = np.concatenate([[0], np.cumsum(np.asarray(x))[:-1]])
    np.testing.assert_allclose(k(x), ref, rtol=1e-5)


def test_cummax():
    x = jnp.asarray(np.random.default_rng(2).standard_normal(6000, dtype=np.float32))
    k = InclusiveScanKernel(np.float32, "fmaxf(a,b)")
    np.testing.assert_allclose(k(x), np.maximum.accumulate(np.asarray(x)))


@given(n=st.integers(1, 9000), seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_scan_property_any_size(n, seed):
    """Two-pass blocked scan must be exact for every element count."""
    x = jnp.asarray(np.random.default_rng(seed).integers(0, 5, n).astype(np.float32))
    k = InclusiveScanKernel(np.float32, "a+b", block_n=1024)
    np.testing.assert_allclose(k(x), np.cumsum(np.asarray(x)), rtol=1e-5)


def test_unsupported_scan_op():
    with pytest.raises(NotImplementedError):
        InclusiveScanKernel(np.float32, "a^b")


# ------------------------------------------------------------- curandom
def test_curand_streams_differ_and_seed_resets():
    curandom.seed(7)
    a = curandom.rand((1000,))
    b = curandom.rand((1000,))
    assert not np.allclose(a, b)
    curandom.seed(7)
    a2 = curandom.rand((1000,))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a2))
    assert float(a.min()) >= 0.0 and float(a.max()) < 1.0


def test_paper_fig4_verbatim():
    """The paper's Fig. 4a program, using our curand + ElementwiseKernel."""
    from repro.core import ElementwiseKernel
    import repro.core.array as gpuarray

    x = curandom.rand((500000,))
    y = curandom.rand((500000,))
    z = gpuarray.empty_like(gpuarray.RTCGArray(x))

    lin_comb = ElementwiseKernel(
        "float a, float *x, float b, float *y, float *z",
        "z[i] = a*x[i] + b*y[i]")
    out = lin_comb(5, x, 6, y, z.value)
    np.testing.assert_allclose(out, 5 * x + 6 * y, rtol=1e-5, atol=1e-5)
