"""Dispatch engine + fusion planner tests (the launch-path contract).

Covers: bucketing math, driver reuse across shape churn (the
``<= ceil(log2(range)) + 1`` acceptance bound), cross-instance driver
sharing, LRU eviction bounding the cache, runtime-n masking in
reductions, DAG map-reduce fusion vs NumPy, and hybrid autotuning.
"""

import numpy as np
import pytest
import jax.numpy as jnp

import repro.core.array as ga
from repro.core import backends, dispatch
from repro.core.cache import DiskCache, LRUCache
from repro.core.elementwise import ElementwiseKernel
from repro.core.reduction import ReductionKernel
from repro.core.scan import InclusiveScanKernel

rng = np.random.default_rng(7)


# ------------------------------------------------------------ bucket math
def test_next_pow2():
    assert [dispatch.next_pow2(x) for x in (1, 2, 3, 7, 8, 9, 1000)] == \
        [1, 2, 4, 8, 8, 16, 1024]


@pytest.mark.parametrize("block_rows", [8, 32, 128])
def test_bucket_rows_properties(block_rows):
    prev = 0
    for n in (1, 127, 128, 129, 4096, 100_000, 999_999):
        b = dispatch.bucket_rows(n, block_rows)
        assert b % block_rows == 0                      # grid divides
        assert b * dispatch.LANES >= n                  # fits the data
        assert b & (b - 1) == 0                         # power of two
        assert b >= prev                                # monotone in n
        prev = b


def test_n_bucket_collapses_a_2x_range():
    buckets = {dispatch.n_bucket(n) for n in range(4096 * 128, 8192 * 128, 4096)}
    assert len(buckets) <= 2


# ------------------------------------------------- driver reuse / sharing
def test_shape_churn_compiles_log_many_drivers():
    """64 calls with n sweeping a 2x range -> <= ceil(log2(2)) + 1 drivers."""
    k = ElementwiseKernel("float *o, float *v", "o[i] = 3*v[i] - 1")
    c0 = dispatch.compile_count()
    for n in np.linspace(4096, 8191, 64).astype(int):
        v = jnp.asarray(rng.standard_normal(int(n)).astype(np.float32))
        np.testing.assert_allclose(k(v, v), 3 * v - 1, rtol=1e-5, atol=1e-5)
    assert dispatch.compile_count() - c0 <= 2


def test_identical_kernels_share_drivers():
    src_args = ("float *o, float *v", "o[i] = v[i] * v[i]")
    a, b = ElementwiseKernel(*src_args), ElementwiseKernel(*src_args)
    v = jnp.asarray(rng.standard_normal(3000).astype(np.float32))
    a(v, v)
    c0 = dispatch.compile_count()
    np.testing.assert_allclose(b(v, v), v * v, rtol=1e-5)
    assert dispatch.compile_count() == c0  # second instance: pure cache hit


def test_reduction_runtime_n_mask_across_bucket():
    """One reduction driver serves many n; the runtime mask keeps padding
    out of the result for every one of them."""
    dot = ReductionKernel(np.float32, "0", "a+b", "x[i]*y[i]",
                          "float *x, float *y")
    c0 = dispatch.compile_count()
    for n in (2049, 2500, 3000, 3500, 4096):
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        y = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        assert float(dot(x, y)) == pytest.approx(float(x @ y), abs=5e-2)
    assert dispatch.compile_count() - c0 <= 1  # all n share one bucket


def test_scan_bucketed_across_sizes():
    cumsum = InclusiveScanKernel(np.float32, "a+b")
    c0 = dispatch.compile_count()
    for n in (100, 3000, 4096, 5000, 8000):
        v = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        np.testing.assert_allclose(cumsum(v), jnp.cumsum(v),
                                   rtol=1e-4, atol=1e-3)
    # 100..4096 share the 1-block bucket; 5000/8000 the 2-block bucket
    assert dispatch.compile_count() - c0 <= 2


# ------------------------------------------------------------ LRU bounds
def test_lru_cache_unit():
    c = LRUCache(maxsize=2)
    c.put("a", 1); c.put("b", 2)
    assert c.get("a") == 1          # refresh a
    c.put("c", 3)                   # evicts b (LRU)
    assert len(c) == 2 and "b" not in c and "a" in c and "c" in c
    assert c.evictions == 1 and c.get("b", "gone") == "gone"


def test_driver_lru_eviction_bounds_cache_and_rebuilds(monkeypatch):
    monkeypatch.setattr(dispatch, "_driver_cache", LRUCache(maxsize=2))
    v = jnp.asarray(rng.standard_normal(500).astype(np.float32))
    kernels = [ElementwiseKernel("float *o, float *v", f"o[i] = v[i] + {j}")
               for j in range(4)]
    for j, k in enumerate(kernels):
        np.testing.assert_allclose(k(v, v), v + j, rtol=1e-5)
    assert len(dispatch.driver_cache()) <= 2
    assert dispatch.driver_cache().evictions >= 2
    # evicted driver rebuilds transparently and stays correct
    c0 = dispatch.compile_count()
    np.testing.assert_allclose(kernels[0](v, v), v + 0, rtol=1e-5)
    assert dispatch.compile_count() == c0 + 1


def test_multiplicative_scan_with_zero_block_total():
    """cumprod carry must not divide by a zero block product (NaN bug)."""
    cumprod = InclusiveScanKernel(np.float32, "a*b")
    v = np.full(10_000, 1.0001, np.float32)  # > block_n: multi-block carry
    v[100] = 0.0                             # zeroes block 0's total
    got = np.asarray(cumprod(jnp.asarray(v)))
    ref = np.cumprod(v, dtype=np.float64).astype(np.float32)
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-6)


def test_mismatched_vector_lengths_raise():
    """Bucket padding must never silently zero-fill a short argument."""
    k = ElementwiseKernel("float *z, float *x, float *y", "z[i] = x[i] + y[i]")
    x = jnp.ones(1000, jnp.float32)
    short = jnp.ones(400, jnp.float32)
    with pytest.raises(ValueError, match="expected 1000"):
        k(x, x, short)
    dot = ReductionKernel(np.float32, "0", "a+b", "x[i]*y[i]",
                          "float *x, float *y")
    with pytest.raises(ValueError, match="'y' has 400"):
        dot(x, short)


# --------------------------------------------------- DAG map-reduce fusion
def test_fused_mapreduce_matches_numpy_single_launch():
    x = rng.standard_normal(3001).astype(np.float32)
    y = rng.standard_normal(3001).astype(np.float32)
    X, Y = ga.to_gpu(x), ga.to_gpu(y)

    l0 = dispatch.launch_count()
    got = float((X * 2 + Y * 3 - ga.exp(X)).sum())
    assert dispatch.launch_count() - l0 == 1    # ONE generated kernel
    ref = float(np.sum(2 * x + 3 * y - np.exp(x)))
    assert got == pytest.approx(ref, rel=1e-4)

    l0 = dispatch.launch_count()
    got_unfused = float((X * 2 + Y * 3 - ga.exp(X)).sum(fuse=False))
    assert dispatch.launch_count() - l0 == 2    # map, then reduce
    assert got_unfused == pytest.approx(ref, rel=1e-4)


def test_fused_mapreduce_max_min_dot_mean():
    x = rng.standard_normal(2050).astype(np.float32)
    y = rng.standard_normal(2050).astype(np.float32)
    X, Y = ga.to_gpu(x), ga.to_gpu(y)
    assert float((X * X).max()) == pytest.approx(float(np.max(x * x)), rel=1e-5)
    assert float((X + Y).min()) == pytest.approx(float(np.min(x + y)), rel=1e-4)
    assert float(X.dot(Y)) == pytest.approx(float(x @ y), abs=2e-2)
    assert float((2 * X).mean()) == pytest.approx(float(np.mean(2 * x)), abs=1e-4)


def test_fusion_planner_contract():
    x = rng.standard_normal(100).astype(np.float32)
    X = ga.to_gpu(x)
    expr = (2 * X + 1)._expr
    p = ga.plan(expr, reduce_expr="a+b", neutral="0")
    assert p.kernel_launches == 1
    assert p.snippet.count("v0") >= 1 and len(p.scalars) == 2
    # isomorphic DAG (different scalar values) -> same generated kernel
    p2 = ga.plan((5 * X + 9)._expr, reduce_expr="a+b", neutral="0")
    assert p2.key == p.key
    # ... but a different neutral element is a different kernel
    p3 = ga.plan((5 * X + 9)._expr, reduce_expr="a+b", neutral="100")
    assert p3.key != p.key
    n0 = len(ga._reduce_cache)
    p.launch(); p2.launch()
    assert len(ga._reduce_cache) == n0 + 1


# ------------------------------------------------------- hybrid autotune
def test_hybrid_autotune_prunes_and_transfers_across_bucket(tmp_path):
    k = ElementwiseKernel("float *o, float *v", "o[i] = 2*v[i] + 1")
    cache = DiskCache("tune", root=tmp_path)
    v = jnp.asarray(rng.standard_normal(100_000).astype(np.float32))
    rep = k.autotune(v, v, cache=cache, repeats=1, warmup=1)
    pruned = [r for r in rep.results if r.error == "pruned by analytic model"]
    timed = [r for r in rep.results if r.ok]
    assert timed and pruned                      # model pruned, clock decided
    assert rep.best in [r.params for r in timed]
    be = backends.get_backend().name
    assert k._tuned[(be, dispatch.n_bucket(100_000))] == rep.best["block_rows"]
    # same bucket, different exact n -> tuning-cache hit, no re-timing
    v2 = jnp.asarray(rng.standard_normal(98_304).astype(np.float32))
    rep2 = k.autotune(v2, v2, cache=cache, repeats=1, warmup=1)
    assert rep2.cached and rep2.best == rep.best


def test_autotuner_hybrid_requires_cost_fn():
    from repro.core.autotune import Autotuner
    with pytest.raises(ValueError):
        Autotuner("x", builder=lambda **kw: (lambda: None), measure="hybrid")
