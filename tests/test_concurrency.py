"""Concurrency tests for the shared caches and counters the serving
runtime leans on (PR 5).

The coalescing executor flushes from a worker thread while request
threads keep submitting and other code paths evaluate plans directly —
so the shared driver `LRUCache` (`get_or_create` under eviction
pressure) and the backend-keyed compile/launch counters must be
race-free.  These tests hammer exactly those two surfaces.
"""

import threading
from pathlib import Path

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.core.array as ga
from repro.core import dispatch
from repro.core.cache import DiskCache, LRUCache

rng = np.random.default_rng(17)


def _run_threads(n, target):
    errors: list = []

    def wrap(i):
        try:
            target(i)
        except BaseException as e:  # noqa: BLE001 - surface in main thread
            errors.append(e)

    threads = [threading.Thread(target=wrap, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]


# ------------------------------------------------------- LRU under load
def test_lru_get_or_create_threaded_eviction():
    """8 threads x 200 lookups over 16 keys against a 4-slot LRU: every
    call must return the value its factory builds for that key (never a
    neighbour's), with eviction churning constantly."""
    cache = LRUCache(maxsize=4)

    def target(tid):
        r = np.random.default_rng(tid)
        for _ in range(200):
            k = int(r.integers(0, 16))
            val = cache.get_or_create(("key", k), lambda k=k: ("value", k))
            assert val == ("value", k)

    _run_threads(8, target)
    stats = cache.stats()
    assert stats["size"] <= 4
    assert stats["evictions"] > 0          # pressure was real
    assert stats["hits"] + stats["misses"] >= 8 * 200


def test_lru_resize_while_hammered():
    cache = LRUCache(maxsize=32)
    stop = threading.Event()

    def churn(tid):
        r = np.random.default_rng(tid)
        while not stop.is_set():
            k = int(r.integers(0, 64))
            assert cache.get_or_create(k, lambda k=k: k * 3) == k * 3

    threads = [threading.Thread(target=churn, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    try:
        for size in (16, 4, 64, 2, 8):
            cache.resize(size)
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert len(cache) <= 8


# ------------------------------------- planner under driver-cache churn
def test_threaded_plans_share_driver_cache_under_eviction():
    """Concurrent plan evaluations on BOTH backends against a shrunken
    shared driver cache: evictions force rebuilds mid-traffic and every
    thread must still get numerically correct results — the runtime
    executor's flush path depends on exactly this property."""
    cache = dispatch.driver_cache()
    old_size = cache.maxsize
    cache.resize(4)                       # brutal eviction pressure
    try:
        sizes = (128, 384, 640, 1152)     # distinct buckets

        def target(tid):
            n = sizes[tid % len(sizes)]
            be = ("pallas", "xla")[tid % 2]
            # per-thread Generator: np Generators are not thread-safe
            x = np.random.default_rng(tid).standard_normal(
                (2, n)).astype(np.float32)
            for _ in range(4):
                out = ga.softmax(ga.RTCGArray(jnp.asarray(x)),
                                 stable=True).evaluate(backend=be).value
                ref = np.asarray(jax.nn.softmax(jnp.asarray(x), axis=-1))
                np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)

        _run_threads(8, target)
        assert len(cache) <= 4
    finally:
        cache.resize(old_size)


# -------------------------------------------- counters under contention
def test_backend_keyed_counters_exact_under_contention():
    """Launch/compile counters are lock-protected per backend tag: N
    threads x M records must sum exactly — the 2-vs-2·K coalescing
    assertions are meaningless if counts can be lost to races."""
    T, M = 8, 250
    launches0 = dispatch.launch_counts()
    compiles0 = dispatch.compile_counts()

    def target(tid):
        be = ("pallas", "xla")[tid % 2]
        for j in range(M):
            dispatch.record_launch(be)
            # distinct keys so every get_or_build is a countable build
            dispatch.get_or_build(("contention", tid, j), lambda: object(),
                                  backend=be)

    _run_threads(T, target)
    launches1 = dispatch.launch_counts()
    compiles1 = dispatch.compile_counts()
    for be in ("pallas", "xla"):
        assert launches1.get(be, 0) - launches0.get(be, 0) == (T // 2) * M
        assert compiles1.get(be, 0) - compiles0.get(be, 0) == (T // 2) * M


def test_count_contexts_under_concurrent_traffic():
    """count_launches()/count_compiles() deltas stay consistent while
    other threads mutate the same counters (they measure process-wide
    activity; the point is no crash/negative delta under contention)."""
    stop = threading.Event()

    def noise():
        while not stop.is_set():
            dispatch.record_launch("xla")

    t = threading.Thread(target=noise)
    t.start()
    try:
        with dispatch.count_launches() as cl, dispatch.count_compiles() as cc:
            dispatch.record_launch("pallas")
        assert cl.delta >= 1 and cl.by_backend.get("pallas", 0) >= 1
        assert cc.delta == 0
    finally:
        stop.set()
        t.join()


def test_compile_listener_hears_concurrent_builds():
    heard: list = []
    lock = threading.Lock()

    def listener(key, backend):
        with lock:
            heard.append((key, backend))

    dispatch.add_compile_listener(listener)
    try:
        def target(tid):
            for j in range(20):
                dispatch.get_or_build(("listener", tid, j), lambda: object(),
                                      backend="pallas")

        _run_threads(4, target)
    finally:
        dispatch.remove_compile_listener(listener)
    assert len(heard) == 80
    assert all(be == "pallas" for _, be in heard)


def test_count_compiles_counts_real_driver_builds():
    """End-to-end: a cleared dispatch state recompiles inside the
    context manager; a warm second call compiles nothing."""
    x = jnp.asarray(rng.standard_normal((2, 200)).astype(np.float32))
    ga.softmax(ga.RTCGArray(x), stable=True).evaluate(backend="pallas")
    dispatch.clear()
    with dispatch.count_compiles() as cold:
        ga.softmax(ga.RTCGArray(x), stable=True).evaluate(backend="pallas")
    assert cold.delta >= 1 and "pallas" in cold.by_backend
    with dispatch.count_compiles() as warm:
        ga.softmax(ga.RTCGArray(x), stable=True).evaluate(backend="pallas")
    assert warm.delta == 0


# -- cross-process DiskCache.update (PR 8) ------------------------------

_INCREMENT_SNIPPET = """
import sys
from pathlib import Path
from repro.core.cache import DiskCache

root, n = Path(sys.argv[1]), int(sys.argv[2])
cache = DiskCache("xproc", root=root)
for _ in range(n):
    cache.update("counter", lambda v: int(v or 0) + 1, default=0)
print(cache.get("counter"))
"""


def test_diskcache_update_is_cross_process_safe(tmp_path):
    """Two processes each fold N increments into one document through
    `DiskCache.update`; the advisory flock around the read-modify-write
    merge means no increment is ever lost (pre-PR-8 the merge only
    serialized threads, and concurrent processes raced read-vs-rename)."""
    import os
    import subprocess
    import sys

    n = 40
    env = dict(os.environ)
    env["PYTHONPATH"] = (str((Path(__file__).parent.parent / "src"))
                         + os.pathsep + env.get("PYTHONPATH", ""))
    procs = [subprocess.Popen(
        [sys.executable, "-c", _INCREMENT_SNIPPET, str(tmp_path), str(n)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for _ in range(2)]
    for p in procs:
        out, err = p.communicate(timeout=120)
        assert p.returncode == 0, f"increment process failed:\n{err[-2000:]}"
    final = DiskCache("xproc", root=tmp_path).get("counter")
    assert final == 2 * n, f"lost {2 * n - final} updates across processes"


def test_diskcache_update_rereads_disk_not_memo(tmp_path):
    """`update` must merge against the *persisted* value: a second
    DiskCache instance (a stand-in for another process) bumps the
    document, and the first instance's next update sees that bump even
    though its in-memory memo is stale."""
    a = DiskCache("memo", root=tmp_path)
    b = DiskCache("memo", root=tmp_path)
    a.update("k", lambda v: int(v or 0) + 1, default=0)   # a's memo: 1
    b.update("k", lambda v: int(v or 0) + 10, default=0)  # disk: 11
    assert a.update("k", lambda v: int(v or 0) + 1, default=0) == 12
