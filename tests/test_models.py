"""Per-arch smoke tests + decode consistency + model-layer invariants."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.configs.base import LM_SHAPES, applicable_shapes
from repro.configs.registry import all_archs, get_config
from repro.models import transformer
from repro.models.layers import chunked_scan
from repro.models.schema import count_params, init_params
from repro.sharding.partition import NULL_CTX

ARCHS = all_archs()


def _batch_for(cfg, key, B=2, S=16, extra_tok=0):
    toks = jax.random.randint(key, (B, S + extra_tok), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model)) * 0.02
    if cfg.is_encdec:
        batch["frame_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_positions, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config of the same family: one forward/train step on CPU,
    asserting output shapes and no NaNs (assignment requirement)."""
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    assert count_params(params) > 0
    B, S = 2, 16
    batch = _batch_for(cfg, key, B, S)
    out = transformer.forward(cfg, params, batch, mode="train")
    assert out["x"].shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(out["x"])))
    loss, metrics = transformer.forward_train(cfg, params, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    grads = jax.grad(lambda p: transformer.forward_train(cfg, p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode_consistency(arch):
    """prefill(S) + decode(S) must equal the (S+1)-token forward pass."""
    cfg = get_config(arch, smoke=True).replace(
        dtype="float32", attention_impl="naive", capacity_factor=100.0)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 12
    batch = _batch_for(cfg, key, B, S, extra_tok=1)
    full = transformer.forward(cfg, params, batch, mode="train")
    full_logits = transformer.logits_from_hidden(
        cfg, params, full["x"][:, -1:, :], NULL_CTX)[:, 0]
    b2 = {k: (v[:, :S] if k in ("tokens", "labels") else v) for k, v in batch.items()}
    _, cache = transformer.prefill(cfg, params, b2, max_len=S + 4)
    lg, _ = transformer.decode_step(cfg, params, cache,
                                    batch["tokens"][:, S:S + 1], jnp.int32(S))
    np.testing.assert_allclose(lg, full_logits, rtol=2e-4, atol=2e-4)


def test_applicable_shapes_long_context_rule():
    # long_500k only for ssm/hybrid (DESIGN.md §4)
    assert "long_500k" in applicable_shapes(get_config("rwkv6-7b"))
    assert "long_500k" in applicable_shapes(get_config("jamba-v0.1-52b"))
    assert "long_500k" not in applicable_shapes(get_config("deepseek-67b"))
    total_cells = sum(len(applicable_shapes(get_config(a))) for a in ARCHS)
    assert total_cells == 32  # 8 archs x 3 + 2 archs x 4


def test_param_count_analytic_close_to_real():
    for arch in ("internlm2-1.8b", "rwkv6-7b", "jamba-v0.1-52b"):
        cfg = get_config(arch, smoke=True)
        real = count_params(init_params(cfg, jax.random.PRNGKey(0)))
        analytic = cfg.param_count()["total"]
        assert abs(real - analytic) / real < 0.15, (arch, real, analytic)


def test_full_config_param_counts_match_names():
    """Sanity: the full configs land near their published sizes."""
    # moonshot: the assigned 48L x 64e config totals ~28B (the published
    # 16B model is 27L); we follow the assignment spec verbatim.
    expect = {"internlm2-1.8b": (1.5e9, 2.4e9), "deepseek-67b": (6e10, 7.5e10),
              "arctic-480b": (4e11, 5.3e11), "granite-20b": (1.6e10, 2.4e10),
              "phi3-medium-14b": (1.2e10, 1.6e10), "rwkv6-7b": (6e9, 9e9),
              "jamba-v0.1-52b": (4.4e10, 6e10), "qwen2-vl-7b": (6.5e9, 9e9),
              "whisper-tiny": (2e7, 1.2e8), "moonshot-v1-16b-a3b": (1.4e10, 3.2e10)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()["total"]
        assert lo <= n <= hi, (arch, f"{n:.3e}")


@given(T=st.integers(1, 65), chunk=st.sampled_from([1, 4, 16, 64]),
       seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_chunked_scan_matches_plain_scan(T, chunk, seed):
    """Invariant: chunked+checkpointed scan == plain scan, any T/chunk."""
    key = jax.random.PRNGKey(seed)
    xs = jax.random.normal(key, (T, 4))

    def step(c, x):
        c = 0.9 * c + x
        return c, c * 2

    c_ref, ys_ref = jax.lax.scan(step, jnp.zeros(4), xs)
    c_out, ys_out = chunked_scan(step, jnp.zeros(4), xs, chunk=chunk)
    np.testing.assert_allclose(c_out, c_ref, rtol=1e-6)
    np.testing.assert_allclose(ys_out, ys_ref, rtol=1e-6)


def test_chunked_scan_gradient():
    xs = jax.random.normal(jax.random.PRNGKey(0), (32, 4))

    def loss_via(scan_fn):
        def f(w):
            def step(c, x):
                c = c * 0.9 + x * w
                return c, c
            _, ys = scan_fn(step, jnp.zeros(4), xs)
            return jnp.sum(ys ** 2)
        return jax.grad(f)(1.5)

    g_ref = loss_via(jax.lax.scan)
    g_chk = loss_via(lambda s, i, x: chunked_scan(s, i, x, chunk=8))
    np.testing.assert_allclose(g_chk, g_ref, rtol=1e-5)


def test_moe_capacity_drops_tokens_gracefully():
    cfg = get_config("moonshot-v1-16b-a3b", smoke=True).replace(
        dtype="float32", capacity_factor=0.1)  # force heavy dropping
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, jax.random.PRNGKey(0))
    loss, _ = transformer.forward_train(cfg, params, batch)
    assert bool(jnp.isfinite(loss))  # residual path carries dropped tokens


def test_mrope_reduces_to_rope_for_text():
    from repro.models.layers import apply_mrope, apply_rope
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 24))
    pos = jnp.arange(8, dtype=jnp.int32)[None].repeat(2, 0)
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, pos3, 1e4, (4, 4, 4))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
