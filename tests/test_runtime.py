"""Serving runtime tests (PR 5) — coalescing executor, backend
auto-router, warm-start manifest, and the runtime-routed serving paths.

The acceptance trio:

  * K concurrent same-bucket softmax requests inside one flush window
    execute as a 2-launch ``(K, N)`` schedule (via
    `dispatch.count_launches`), not ``2·K``;
  * ``backend="auto"`` routes at least one bucket to each backend under
    recorded telemetry;
  * `runtime.warmup()` from a persisted manifest yields zero new
    compiles when the recorded traffic replays.
"""

import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import runtime as rtm
from repro.core import autotune, dispatch
from repro.core.cache import DiskCache
import repro.core.array as ga

rng = np.random.default_rng(3)


@pytest.fixture
def rt(tmp_path):
    """Isolated runtime: private router + tmp-dir manifest, generous
    window, max_batch=8 (tests submit exactly 8 rows so the flush fires
    deterministically on the last submit, not on a timer)."""
    r = rtm.ServingRuntime(
        backend="auto", window=0.25, max_batch=8,
        router=rtm.BackendRouter(),
        manifest=rtm.WarmStartManifest(
            cache=DiskCache("runtime_manifest", root=tmp_path)))
    yield r
    r.close()


def _submit_wave(rt_, rows, submit):
    futs = [None] * len(rows)

    def one(i):
        futs[i] = submit(rows[i])

    threads = [threading.Thread(target=one, args=(i,))
               for i in range(len(rows))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return [f.result(timeout=120) for f in futs]


# ------------------------------------------------ coalescing executor
def test_coalesced_wave_is_two_launches(rt):
    """K single-row requests from K threads -> ONE (K, N) flush: 2
    generated-kernel launches total instead of 2·K."""
    K, N = 8, 512
    rows = [rng.standard_normal(N).astype(np.float32) for _ in range(K)]
    with dispatch.count_launches() as c:
        outs = _submit_wave(rt, rows, rt.submit_softmax)
    assert c.delta == 2, c.by_backend
    ex = rt.executor.stats()
    assert ex["requests"] == K and ex["flushes"] == 1
    assert ex["coalesce_factor"] == pytest.approx(K)
    assert ex["launches"] == 2
    ref = np.asarray(jax.nn.softmax(jnp.asarray(np.stack(rows)), axis=-1))
    np.testing.assert_allclose(np.stack([np.asarray(o) for o in outs]),
                               ref, atol=1e-5)


def test_distinct_buckets_do_not_coalesce(rt):
    """Rows of different lengths form separate batches (separate keys)."""
    outs = _submit_wave(
        rt, [rng.standard_normal(256).astype(np.float32) for _ in range(4)]
        + [rng.standard_normal(512).astype(np.float32) for _ in range(4)],
        rt.submit_softmax)
    rt.flush()
    assert rt.executor.stats()["flushes"] == 2
    assert outs[0].shape == (256,) and outs[-1].shape == (512,)


def test_submit_rejects_batched_operands(rt):
    with pytest.raises(ValueError, match="single rows"):
        rt.submit_softmax(np.zeros((2, 64), np.float32))


def test_rmsnorm_submissions_coalesce_per_weight(rt):
    w = jnp.asarray(rng.standard_normal(128).astype(np.float32))
    rows = [rng.standard_normal(128).astype(np.float32) for _ in range(8)]
    with dispatch.count_launches() as c:
        outs = _submit_wave(rt, rows, lambda r: rt.submit_rmsnorm(r, w))
    assert c.delta == 2
    X = np.stack(rows)
    ms = np.mean(X * X, axis=-1, keepdims=True)
    ref = X / np.sqrt(ms + 1e-6) * np.asarray(w)
    np.testing.assert_allclose(np.stack([np.asarray(o) for o in outs]),
                               ref, atol=1e-4)


def test_sampler_rides_the_softmax_batch(rt):
    """submit_sample joins the stable-softmax micro-batch; the
    per-request draw is a post-step, so the flush stays at 2 launches."""
    K, N = 8, 128
    rows = [rng.standard_normal(N).astype(np.float32) for _ in range(K)]
    keys = [jax.random.PRNGKey(i) for i in range(K)]
    with dispatch.count_launches() as c:
        toks = _submit_wave(
            rt, list(range(K)),
            lambda i: rt.submit_sample(rows[i], keys[i], temperature=0.8))
    assert c.delta == 2
    assert all(isinstance(t, int) and 0 <= t < N for t in toks)
    assert rt.executor.stats()["flushes"] == 1


def test_executor_error_fans_out_to_futures(rt):
    fut = rt.executor.submit("no-such-family", np.zeros(8, np.float32))
    with pytest.raises(ValueError, match="unknown runtime family"):
        fut.result(timeout=60)


def test_failing_post_step_fails_only_its_own_future(rt):
    """One request's bad post hook (e.g. a broken sampler key) must not
    poison the co-batched requests that already have valid results."""
    def boom(_row):
        raise RuntimeError("bad sampler key")

    rows = [rng.standard_normal(96).astype(np.float32) for _ in range(4)]
    futs = [rt.executor.submit("softmax", r, shared={"stable": True},
                               key_extra=(True,),
                               post=boom if i == 2 else None)
            for i, r in enumerate(rows)]
    rt.flush()
    with pytest.raises(RuntimeError, match="bad sampler key"):
        futs[2].result(timeout=60)
    ref = np.asarray(jax.nn.softmax(jnp.asarray(np.stack(rows)), axis=-1))
    for i in (0, 1, 3):
        np.testing.assert_allclose(np.asarray(futs[i].result(timeout=60)),
                                   ref[i], atol=1e-5)


def test_executor_close_rejects_new_work(tmp_path):
    r = rtm.ServingRuntime(
        backend="pallas", router=rtm.BackendRouter(),
        manifest=rtm.WarmStartManifest(
            cache=DiskCache("runtime_manifest", root=tmp_path)))
    r.close()
    with pytest.raises(RuntimeError, match="closed"):
        r.submit_softmax(np.zeros(8, np.float32))


# ------------------------------------------------- backend auto-router
def test_router_routes_buckets_to_different_backends():
    """The acceptance shape: under recorded telemetry where xla wins the
    small bucket and pallas the large one, auto routes each bucket to
    its winner — at least one bucket per backend."""
    r = rtm.BackendRouter()
    small, large = (1, 2), (64, 32)
    for _ in range(3):
        r.observe("softmax", "xla", small, 0.001)
        r.observe("softmax", "pallas", small, 0.010)
        r.observe("softmax", "pallas", large, 0.002)
        r.observe("softmax", "xla", large, 0.020)
    assert r.choose("softmax", small) == "xla"
    assert r.choose("softmax", large) == "pallas"
    table = r.route_table()
    assert set(table.values()) == {"xla", "pallas"}


def test_router_explores_unmeasured_backends_first():
    r = rtm.BackendRouter(backends=("pallas", "xla"))
    b = (4, 4)
    assert r.choose("f", b) == "pallas"     # nothing measured: first
    r.observe("f", "pallas", b, 0.001)
    assert r.choose("f", b) == "xla"        # xla still unmeasured
    r.observe("f", "xla", b, 0.005)
    assert r.choose("f", b) == "pallas"     # now exploit the argmin


def test_router_periodic_reexploration():
    r = rtm.BackendRouter(explore_every=5)
    b = (4, 4)
    r.observe("f", "pallas", b, 0.001)
    r.observe("f", "xla", b, 0.005)
    picks = [r.choose("f", b) for _ in range(10)]
    assert picks.count("xla") >= 1          # runner-up gets re-measured
    assert picks.count("pallas") > picks.count("xla")


def test_router_seeded_from_autotuner_winners():
    """`tune_per_bucket` winner hooks seed (backend, bucket) priors that
    `estimate` falls back to before a family has its own telemetry."""
    r = rtm.BackendRouter()
    autotune.notify_winner("eltwise.fused_ab", "xla", (16, 32), 0.0007)
    autotune.notify_winner("eltwise.fused_ab", "xla", 128, 0.0021)
    assert r.estimate("anything", "xla", (16, 32)) == pytest.approx(0.0007)
    assert r.estimate("anything", "xla", (128,)) == pytest.approx(0.0021)
    assert r.estimate("anything", "pallas", (16, 32)) is None


def test_router_seed_from_block_cost():
    r = rtm.BackendRouter()
    cost = autotune.BlockCost(flops=1e6, hbm_bytes=1e6, vmem_bytes=1.0, grid=4)
    r.seed_from_cost("softmax", (8, 8), cost)
    est = r.estimate("softmax", "pallas", (8, 8))
    assert est == pytest.approx(cost.seconds())
    # priors never suppress first-observation exploration
    assert r.choose("softmax", (8, 8)) == "pallas"
    r.observe("softmax", "pallas", (8, 8), 0.5)
    assert r.choose("softmax", (8, 8)) == "xla"


def test_evaluate_backend_auto_routes_through_default_router():
    prev = rtm.set_default_router(rtm.BackendRouter())
    try:
        x = rng.standard_normal((4, 256)).astype(np.float32)
        out = ga.softmax(ga.RTCGArray(jnp.asarray(x)),
                         stable=True).evaluate(backend="auto").value
        ref = np.asarray(jax.nn.softmax(jnp.asarray(x), axis=-1))
        np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)
        routes = rtm.default_router().stats()["routes"]
        assert sum(routes.values()) == 1
        assert next(iter(routes)).startswith("plan:")
    finally:
        rtm.set_default_router(prev)


def test_layers_backend_auto_uses_default_runtime(rt):
    from repro.models import layers

    prev = rtm.set_default_runtime(rt)
    try:
        x = rng.standard_normal((4, 192)).astype(np.float32)
        out = layers.fused_softmax(x, backend="auto")
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(jax.nn.softmax(jnp.asarray(x), -1)),
            atol=1e-5)
        w = rng.standard_normal(192).astype(np.float32)
        out2 = layers.rtcg_rmsnorm(x, w, backend="auto")
        ms = np.mean(x * x, axis=-1, keepdims=True)
        np.testing.assert_allclose(np.asarray(out2),
                                   x / np.sqrt(ms + 1e-6) * w, atol=1e-4)
        assert len(rt.manifest) >= 2       # both families recorded
        assert rt.router.stats()["routes"]
    finally:
        rtm.set_default_runtime(prev)


def test_get_backend_auto_raises_helpfully():
    from repro.core import backends

    with pytest.raises(ValueError, match="serving runtime"):
        backends.get_backend("auto")


# ---------------------------------------------- warm-start manifest
def test_manifest_records_dedup_and_persist(tmp_path):
    cache = DiskCache("runtime_manifest", root=tmp_path)
    m = rtm.WarmStartManifest(cache=cache)
    assert m.record("softmax", (8, 512), "float32", "pallas",
                    {"stable": True})
    # same (family, bucket, dtype, backend, params) cell -> dedup
    assert not m.record("softmax", (8, 512), "float32", "pallas",
                        {"stable": True})
    assert m.record("softmax", (8, 512), "float32", "xla", {"stable": True})
    assert len(m) == 2
    # a fresh manifest over the same cache sees the persisted doc
    m2 = rtm.WarmStartManifest(cache=DiskCache("runtime_manifest",
                                               root=tmp_path))
    assert len(m2) == 2
    fams = {e["family"] for e in m2.entries()}
    assert fams == {"softmax"}


def test_warmup_replay_yields_zero_compiles(rt):
    """The compiler-cache-for-fleets contract: record traffic, simulate a
    fresh process (drop every compiled driver), warmup() from the
    manifest — replaying the same traffic compiles NOTHING."""
    K, N = 8, 384
    X = np.stack([rng.standard_normal(N).astype(np.float32)
                  for _ in range(K)])

    def traffic():
        for _ in range(5):   # enough calls that auto explores BOTH backends
            rt.softmax(X, stable=True)
        _submit_wave(rt, list(X), rt.submit_softmax)

    traffic()
    assert len(rt.manifest) >= 2   # both explored backends recorded

    dispatch.clear()               # fresh-process simulation
    report = rt.warmup()
    assert report["replayed"] == report["entries"] == len(rt.manifest)
    assert report["compiles"] > 0  # warmup itself pays the builds
    assert not report["errors"]
    with dispatch.count_compiles() as cc:
        traffic()
    assert cc.delta == 0, cc.by_backend


def test_warmup_covers_observed_driver_keys(rt):
    X = np.stack([rng.standard_normal(256).astype(np.float32)
                  for _ in range(4)])
    rt.softmax(X, stable=True)
    dispatch.clear()
    report = rt.warmup()
    assert report["covered_keys"] > 0
    assert report["observed_keys"] >= report["covered_keys"]


# -------------------------------------------- runtime-routed serving
def test_runtime_sample_matches_distribution_shape(rt):
    logits = rng.standard_normal((4, 64)).astype(np.float32)
    toks0 = rt.sample(logits, jax.random.PRNGKey(0), temperature=0.0)
    np.testing.assert_array_equal(np.asarray(toks0),
                                  np.argmax(logits, axis=-1))
    toks = rt.sample(logits, jax.random.PRNGKey(1), temperature=0.9)
    toks_again = rt.sample(logits, jax.random.PRNGKey(1), temperature=0.9)
    assert toks.shape == (4,) and toks.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks_again))
    assert all(0 <= int(t) < 64 for t in np.asarray(toks))


def test_engine_sample_routes_through_runtime(rt):
    """Engine._sample with a runtime: concrete logits go through the
    runtime's routed softmax (recorded in the manifest)."""
    from repro.serving.engine import Engine

    eng = Engine.__new__(Engine)   # sampling needs no model state
    eng.runtime = rt
    logits = jnp.asarray(rng.standard_normal((2, 96)).astype(np.float32))
    before = len(rt.manifest)
    tok = eng._sample(logits, jax.random.PRNGKey(0), temperature=0.7)
    assert tok.shape == (2,)
    assert len(rt.manifest) > before
    # greedy path ignores the runtime
    np.testing.assert_array_equal(
        np.asarray(eng._sample(logits, jax.random.PRNGKey(0), 0.0)),
        np.argmax(np.asarray(logits), axis=-1))


def test_request_queue_ids_and_padding_strip():
    """RequestQueue carries ids + original prompt lengths: done entries
    map back to their submitter with padding stripped."""
    from repro.serving.engine import GenerationResult, RequestQueue

    class FakeEngine:
        def __init__(self):
            self.calls = []

        def generate(self, prompts, steps, *, temperature=0.0, seed=0,
                     extra_batch=None):
            self.calls.append(np.asarray(prompts))
            B, S = prompts.shape
            toks = np.tile(np.arange(steps, dtype=np.int32), (B, 1)) + 100
            return GenerationResult(toks, steps, S)

    q = RequestQueue()
    prompts = [np.arange(3, dtype=np.int32) + 1,
               np.arange(7, dtype=np.int32) + 10,
               np.arange(5, dtype=np.int32) + 50]
    ids = [q.submit(p) for p in prompts]
    assert ids == [0, 1, 2]
    eng = FakeEngine()
    done = q.run(eng, batch_size=2, steps=4)
    assert [r.request_id for r in done] == ids
    for r, p in zip(done, prompts):
        assert r.prompt_len == len(p)
        np.testing.assert_array_equal(r.prompt, p)           # unpadded
        np.testing.assert_array_equal(r.sequence[:len(p)], p)
        assert r.sequence.shape == (len(p) + 4,)
        assert r.padded_len >= r.prompt_len
    # first block padded to its longest member (7), second block exact
    assert eng.calls[0].shape == (2, 7) and eng.calls[1].shape == (1, 5)
    # left-padding really happened for the short prompt of block 0 ...
    np.testing.assert_array_equal(eng.calls[0][0][:4], 0)
    # ... and result_for maps ids to results
    assert q.result_for(ids[1]).prompt_len == 7
    assert q.result_for(999) is None


def test_runtime_stats_shape(rt):
    rt.softmax(np.stack([rng.standard_normal(128).astype(np.float32)]))
    st = rt.stats()
    assert {"backend", "executor", "router", "manifest",
            "dispatch"} <= set(st)
    assert st["manifest"]["entries"] >= 1
    assert "coalesce_factor" in st["executor"]
    assert "routes" in st["router"]
    # and the module-level convenience reads the default runtime
    assert "router" in rtm.stats()
