import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

# NOTE: tests run with the real single CPU device. Multi-device tests
# spawn subprocesses that set --xla_force_host_platform_device_count
# themselves (never set globally here — see the dry-run contract).


def pytest_configure(config):
    # CI chaos leg (DESIGN.md §10): REPRO_CHAOS=compile:0.05,launch:0.05
    # arms a process-lifetime transient fault plan before any test runs;
    # the whole tier-1 suite must stay green under it.  A no-op when the
    # variable is unset.
    if os.environ.get("REPRO_CHAOS"):
        from repro.runtime import faults

        faults.install_env_plan()


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 900):
    """Run a python snippet in a subprocess with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = env.get("XLA_FLAGS", "") + \
        f" --xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nSTDOUT:\n{proc.stdout[-4000:]}\n"
            f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_with_devices
