"""Fusion planner v2 tests — reductions as interior DAG nodes.

Covers: the softmax/centering/variance launch schedules (reduce waves +
ONE fused epilogue), `plan_many` multi-accumulator sibling reductions,
dtype-faithful plans (int32 exactness, scalar args typed from the plan
dtype), finfo/iinfo-derived max/min neutrals, ``__rpow__``, the bounded
LRU kernel caches, per-bucket autotuning for Reduction/Scan kernels,
and the model-level `fused_softmax` host path — plus property-style
sweeps (via the hypothesis stub) across bucket-boundary sizes.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_stub import given, settings, st

import repro.core.array as ga
from repro.core import backends, dispatch
from repro.core.cache import LRUCache

rng = np.random.default_rng(11)

# bucket-boundary element counts: rows = n/128, bucket flips at pow2 rows
BOUNDARY_SIZES = (1023, 1024, 1025)


@pytest.fixture(scope="module", params=["pallas", "xla"], autouse=True)
def rtcg_backend(request):
    """Run the whole suite once per execution backend (PR 4): numerics,
    launch-count schedules and cache behavior must be identical under
    ``REPRO_BACKEND=pallas`` and ``REPRO_BACKEND=xla``.  Module-scoped:
    kernels resolve the env selection per call, so flipping it between
    module runs re-routes every generated kernel."""
    import os

    old = os.environ.get("REPRO_BACKEND")
    os.environ["REPRO_BACKEND"] = request.param
    yield request.param
    if old is None:
        os.environ.pop("REPRO_BACKEND", None)
    else:
        os.environ["REPRO_BACKEND"] = old


def _launches(fn):
    with dispatch.count_launches() as c:
        out = fn()
    return out, c.delta


# ------------------------------------------------- interior reductions
@pytest.mark.parametrize("n", BOUNDARY_SIZES)
def test_softmax_two_launches_matches_jax(n):
    """x.exp() / x.exp().sum() == reduce + ONE fused epilogue (<= 2)."""
    x = rng.standard_normal(n).astype(np.float32)
    X = ga.to_gpu(x)
    sm, delta = _launches(lambda: (X.exp() / X.exp().sum()).value)
    assert delta <= 2
    np.testing.assert_allclose(np.asarray(sm),
                               np.asarray(jax.nn.softmax(jnp.asarray(x))),
                               atol=1e-5)


def test_ga_softmax_stable_and_unstable():
    x = rng.standard_normal(3000).astype(np.float32) * 8
    X = ga.to_gpu(x)
    ref = np.asarray(jax.nn.softmax(jnp.asarray(x)))
    fast, d_fast = _launches(lambda: ga.softmax(X).value)
    safe, d_safe = _launches(lambda: ga.softmax(X, stable=True).value)
    assert d_fast <= 2 and d_safe <= 3
    np.testing.assert_allclose(np.asarray(fast), ref, atol=1e-5)
    np.testing.assert_allclose(np.asarray(safe), ref, atol=1e-5)


def test_centering_schedule_and_value():
    """(x - x.mean()) plans a reduce + one epilogue that takes the reduced
    scalar as an s<j> arg — 2 launches, no eager fallback."""
    x = rng.standard_normal(2500).astype(np.float32)
    X = ga.to_gpu(x)
    c, delta = _launches(lambda: (X - X.mean()).value)
    assert delta == 2
    np.testing.assert_allclose(np.asarray(c), x - x.mean(), atol=1e-5)


def test_variance_nested_reductions():
    """((x - x.mean())**2).mean(): two dependent reduce waves, the /n
    folds on the host — 2 launches total."""
    x = rng.standard_normal(2500).astype(np.float32)
    X = ga.to_gpu(x)
    v, delta = _launches(lambda: float(((X - X.mean()) ** 2).mean()))
    assert delta == 2
    assert v == pytest.approx(float(x.var()), rel=1e-4)


def test_terminal_reduce_still_single_launch():
    x = rng.standard_normal(2048).astype(np.float32)
    X = ga.to_gpu(x)
    got, delta = _launches(lambda: float((X * 3 - 1).sum()))
    assert delta == 1
    assert got == pytest.approx(float(np.sum(x * 3 - 1)), rel=1e-4)


def test_reduction_feeding_reduction_feeding_elementwise():
    """Normalize by the variance: epilogue consumes two reduce waves."""
    x = rng.standard_normal(2000).astype(np.float32)
    X = ga.to_gpu(x)
    out, delta = _launches(
        lambda: ((X - X.mean()) / (((X - X.mean()) ** 2).mean() + 1e-6).sqrt()).value)
    assert delta <= 4
    mu, var = x.mean(), x.var()
    np.testing.assert_allclose(np.asarray(out), (x - mu) / np.sqrt(var + 1e-6),
                               atol=1e-4)


# --------------------------------------------------------- plan_many
def test_plan_many_sibling_reductions_one_launch():
    """min/max/sum quantization stats share one multi-accumulator kernel."""
    x = rng.standard_normal(3000).astype(np.float32)
    X = ga.to_gpu(x)
    chain = X * 2 + 1
    sched = ga.plan_many([chain.min(), chain.max(), chain.sum()])
    assert sched.kernel_launches == 1
    (lo, hi, tot), delta = _launches(sched.launch)
    assert delta == 1
    ref = x * 2 + 1
    assert float(lo) == pytest.approx(float(ref.min()), rel=1e-5)
    assert float(hi) == pytest.approx(float(ref.max()), rel=1e-5)
    assert float(tot) == pytest.approx(float(ref.sum()), rel=1e-3)


def test_plan_many_mixed_roots():
    """Vector + reduce + host-scalar roots in one schedule."""
    x = rng.standard_normal(1500).astype(np.float32)
    X = ga.to_gpu(x)
    sched = ga.plan_many([X * 2, X.sum(), X.mean()])
    # one reduce wave (sum feeds both reduce root and mean), one epilogue
    assert sched.kernel_launches <= 3
    vec, s, m = sched.launch()
    np.testing.assert_allclose(np.asarray(vec), x * 2, rtol=1e-5)
    assert float(s) == pytest.approx(float(x.sum()), abs=1e-2)
    assert float(m) == pytest.approx(float(x.mean()), abs=1e-5)


def test_plan_many_shares_map_chain_kernel_cache():
    """Isomorphic sibling-reduction schedules reuse one generated kernel."""
    x = rng.standard_normal(800).astype(np.float32)
    y = rng.standard_normal(800).astype(np.float32)
    X, Y = ga.to_gpu(x), ga.to_gpu(y)
    s1 = ga.plan_many([(X * 2).min(), (X * 2).max()])
    s2 = ga.plan_many([(Y * 5).min(), (Y * 5).max()])
    assert s1.steps[0].key == s2.steps[0].key
    n0 = len(ga._reduce_cache)
    s1.launch(); s2.launch()
    # the generated kernel is shared by identity and the cache grew by at
    # most one entry (zero when an earlier isomorphic plan — e.g. the
    # other backend's module run — already populated it: plan keys are
    # backend-independent, only *drivers* are backend-keyed)
    assert s1.steps[0].kernel() is s2.steps[0].kernel()
    assert len(ga._reduce_cache) <= n0 + 1


# --------------------------------------------------- dtype faithfulness
def test_int32_plans_are_exact():
    """int32 chain reduces in int32 — no float32 round-trip (satellite:
    ScalarArg was hard-coded float32 and scalars coerced via float())."""
    xi = rng.integers(-1000, 1000, 4000).astype(np.int32)
    XI = ga.to_gpu(xi)
    s = (XI * 3 + 7).sum()
    assert jnp.dtype(s.dtype) == jnp.int32
    assert int(s) == int((xi.astype(np.int64) * 3 + 7).sum())


def test_int_neutrals_from_iinfo():
    """All-negative int max (and all-positive min) breaks ±3e38 neutrals."""
    xi = (-rng.integers(1, 1000, 2000)).astype(np.int32)
    XI = ga.to_gpu(xi)
    assert int(XI.max()) == int(xi.max())
    assert int((-XI).min()) == int((-xi).min())


def test_float_neutral_literals_come_from_finfo():
    assert ga._neutral_for("max", jnp.float32) == repr(float(jnp.finfo(jnp.float32).min))
    assert ga._neutral_for("min", jnp.float32) == repr(float(jnp.finfo(jnp.float32).max))
    assert ga._neutral_for("max", jnp.int32) == str(jnp.iinfo(jnp.int32).min)
    assert ga._neutral_for("sum", jnp.int32) == "0"


def test_mixed_dtype_promotion():
    """int leaves with a float scalar promote the whole plan to float."""
    xi = rng.integers(-50, 50, 1000).astype(np.int32)
    XI = ga.to_gpu(xi)
    out = (XI * 0.5).value
    assert jnp.issubdtype(out.dtype, jnp.floating)
    np.testing.assert_allclose(np.asarray(out), xi * 0.5, rtol=1e-6)
    # int mean promotes via the /n host fold
    m = XI.mean()
    assert jnp.issubdtype(jnp.dtype(m.dtype), jnp.floating)
    assert float(m) == pytest.approx(float(xi.mean()), abs=1e-5)


def test_mixed_dtype_roots_stay_exact():
    """An int chain sharing a plan_many schedule with a float chain must
    keep int scalar slots — promoting with the *other* root's dtype
    would compute (v0 + s0) in float32 and drop bits past 2**24."""
    xi = (np.arange(1000, dtype=np.int32) + 16_777_200)
    xf = rng.standard_normal(1000).astype(np.float32)
    XI, XF = ga.to_gpu(xi), ga.to_gpu(xf)
    got_i, got_f = ga.plan_many([XI + 2, XF * 1.5]).launch()
    assert jnp.dtype(got_i.dtype) == jnp.int32
    np.testing.assert_array_equal(np.asarray(got_i), xi + 2)
    np.testing.assert_allclose(np.asarray(got_f), xf * 1.5, rtol=1e-6)


def test_rpow_and_output_template():
    """2 ** x works (satellite __rpow__) and the epilogue allocates a real
    output template instead of aliasing leaves[0]."""
    x = rng.standard_normal(1200).astype(np.float32)
    X = ga.to_gpu(x)
    out = (2 ** X).value
    np.testing.assert_allclose(np.asarray(out), 2.0 ** x, rtol=1e-5)
    # int leaf, float result: the old leaves[0].astype hack would have
    # produced an int template; the plan dtype must win
    xi = rng.integers(0, 5, 1200).astype(np.int32)
    XI = ga.to_gpu(xi)
    out2 = (1.5 ** XI).value
    assert jnp.issubdtype(out2.dtype, jnp.floating)
    np.testing.assert_allclose(np.asarray(out2), 1.5 ** xi, rtol=1e-5)


# ------------------------------------------------------- bounded caches
def test_fusion_kernel_caches_are_lru(monkeypatch):
    monkeypatch.setattr(ga, "_kernel_cache", LRUCache(maxsize=2))
    monkeypatch.setattr(ga, "_reduce_cache", LRUCache(maxsize=2))
    x = rng.standard_normal(600).astype(np.float32)
    X = ga.to_gpu(x)
    # four structurally distinct elementwise plans -> evictions
    (X * 2).value; (X + 2).value; (X - 2).value; (X / 2).value
    assert len(ga._kernel_cache) <= 2
    assert ga._kernel_cache.evictions >= 2
    # evicted plan rebuilds transparently and stays correct
    np.testing.assert_allclose(np.asarray((X * 2).value), x * 2, rtol=1e-5)
    # distinct reduce schedules bound the reduce cache the same way
    float((X * 2).sum()); float((X + 2).sum()); float((X - 2).sum())
    assert len(ga._reduce_cache) <= 2


def test_fusion_cache_env_knob():
    assert ga._kernel_cache.maxsize == ga._FUSION_CACHE_SIZE
    assert ga._reduce_cache.maxsize == ga._FUSION_CACHE_SIZE


# ------------------------------------------- per-bucket kernel tuning
def test_reduction_autotune_per_bucket(tmp_path):
    from repro.core.cache import DiskCache
    from repro.core.reduction import ReductionKernel

    dot = ReductionKernel(np.float32, "0", "a+b", "x[i]*y[i]",
                          "float *x, float *y", name="tunedot")
    cache = DiskCache("tune", root=tmp_path)
    v = jnp.asarray(rng.standard_normal(60_000).astype(np.float32))
    rep = dot.autotune(v, v, cache=cache, repeats=1, warmup=1)
    be = backends.get_backend().name
    assert dot._tuned[(be, dispatch.n_bucket(60_000))] == rep.best["block_rows"]
    # same bucket, different exact n -> cached winner, no re-timing
    v2 = jnp.asarray(rng.standard_normal(59_000).astype(np.float32))
    rep2 = dot.autotune(v2, v2, cache=cache, repeats=1, warmup=1)
    assert rep2.cached and rep2.best == rep.best
    # the tuned winner is picked up by plain calls in the bucket
    assert dot._pick_block_rows(59_000, None, be) == rep.best["block_rows"]


def test_scan_autotune_per_bucket(tmp_path):
    from repro.core.cache import DiskCache
    from repro.core.scan import InclusiveScanKernel

    cumsum = InclusiveScanKernel(np.float32, "a+b", name="tunescan")
    cache = DiskCache("tune", root=tmp_path)
    v = jnp.asarray(rng.standard_normal(30_000).astype(np.float32))
    rep = cumsum.autotune(v, cache=cache, repeats=1, warmup=1)
    be = backends.get_backend().name
    assert cumsum._tuned[(be, dispatch.n_bucket(30_000))] == rep.best["block_n"]
    assert cumsum._pick_block_n(30_000, None, be) == rep.best["block_n"]
    # tuned block_n stays correct
    np.testing.assert_allclose(np.asarray(cumsum(v)), np.cumsum(np.asarray(v)),
                               rtol=1e-4, atol=1e-3)


def test_multi_accumulator_reduction_kernel_direct():
    from repro.core.reduction import ReductionKernel

    x = jnp.asarray(rng.standard_normal(5000).astype(np.float32))
    stats = ReductionKernel(
        [np.float32] * 3,
        [ga._neutral_for("min", np.float32), ga._neutral_for("max", np.float32), "0"],
        ["fminf(a,b)", "fmaxf(a,b)", "a+b"],
        ["x[i]", "x[i]", "x[i]"], "float *x", name="stats3")
    with dispatch.count_launches() as c:
        lo, hi, tot = stats(x)
    assert c.delta == 1
    assert float(lo) == pytest.approx(float(x.min()), rel=1e-6)
    assert float(hi) == pytest.approx(float(x.max()), rel=1e-6)
    assert float(tot) == pytest.approx(float(x.sum()), abs=5e-2)


# ------------------------------------------------ model-level wiring
def test_fused_softmax_host_path_matches_jax():
    from repro.models.layers import fused_softmax

    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32) * 4)
    with dispatch.count_launches() as c:
        out = fused_softmax(x)
    assert c.delta >= 1  # really went through generated kernels
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jax.nn.softmax(x)), atol=1e-5)
    # traced + batched inputs fall back (no crash, identical numbers)
    xb = jnp.stack([x, x])
    np.testing.assert_allclose(np.asarray(fused_softmax(xb)),
                               np.asarray(jax.nn.softmax(xb, axis=-1)))
    np.testing.assert_allclose(np.asarray(jax.jit(fused_softmax)(x)),
                               np.asarray(jax.nn.softmax(x)), atol=1e-6)


# ------------------------------------------- property-style sweeps
@given(n=st.integers(900, 1200), seed=st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_softmax_property(n, seed):
    r = np.random.default_rng(seed)
    x = r.standard_normal(n).astype(np.float32)
    X = ga.to_gpu(x)
    sm, delta = _launches(lambda: (X.exp() / X.exp().sum()).value)
    assert delta <= 2
    np.testing.assert_allclose(np.asarray(sm),
                               np.asarray(jax.nn.softmax(jnp.asarray(x))),
                               atol=1e-5)


@given(n=st.integers(900, 1200), seed=st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_variance_property(n, seed):
    r = np.random.default_rng(seed)
    x = r.standard_normal(n).astype(np.float32)
    X = ga.to_gpu(x)
    v = float(((X - X.mean()) ** 2).mean())
    assert v == pytest.approx(float(x.var()), rel=1e-3, abs=1e-5)


@pytest.mark.parametrize("n", BOUNDARY_SIZES)
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_reduce_boundary_sizes_across_dtypes(n, dtype):
    if dtype is np.int32:
        x = rng.integers(-100, 100, n).astype(dtype)
        X = ga.to_gpu(x)
        assert int(X.sum()) == int(x.astype(np.int64).sum())
        assert int(X.max()) == int(x.max())
        assert int(X.min()) == int(x.min())
    else:
        x = rng.standard_normal(n).astype(dtype)
        X = ga.to_gpu(x)
        assert float(X.sum()) == pytest.approx(float(x.sum()), abs=5e-2)
        assert float(X.max()) == pytest.approx(float(x.max()), rel=1e-6)
        assert float(X.min()) == pytest.approx(float(x.min()), rel=1e-6)
