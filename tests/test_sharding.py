"""Sharding rules + distributed correctness on an 8-host-device mesh."""

import numpy as np
import pytest
import jax
from jax.sharding import PartitionSpec as P

from repro.sharding.partition import (LOGICAL_RULES, PROFILES, cache_spec_for,
                                      spec_for)


class FakeMesh:
    def __init__(self, names, shape):
        self.axis_names = names
        import numpy as _np
        self.devices = _np.empty(shape)


MESH = FakeMesh(("data", "model"), (16, 16))
MESH3 = FakeMesh(("pod", "data", "model"), (2, 16, 16))


def test_spec_divisible():
    assert spec_for(("vocab", "embed"), (163840, 2048), MESH) == P("model", "data")


def test_spec_indivisible_falls_back_to_replication():
    # the flat (Hk*dh) projection dim CAN shard even for MQA (128 % 16 == 0)
    assert spec_for(("embed", "kv_heads"), (6144, 128), MESH) == P("data", "model")
    # ...but the per-head dims cannot: granite kv=1, arctic 56 heads
    assert spec_for(("batch", None, "kv_heads", None), (256, 4096, 1, 128), MESH) \
        == P("data", None, None, None)
    assert spec_for(("batch", None, "heads", None), (256, 4096, 56, 128), MESH) \
        == P("data", None, None, None)


def test_spec_never_reuses_mesh_axis():
    sp = spec_for(("embed", "embed"), (2048, 2048), MESH)
    assert sp == P("data", None)  # second use of 'data' suppressed


def test_spec_batch_multi_pod():
    assert spec_for(("batch", None), (256, 4096), MESH3) == P(("pod", "data"), None)
    # batch=32: divisible by pod*data=32
    assert spec_for(("batch", None), (32, 1), MESH3) == P(("pod", "data"), None)
    # batch=16: drops 'pod', shards over data only
    assert spec_for(("batch", None), (16, 1), MESH3) == P("data", None)
    # batch=1: replicated
    assert spec_for(("batch", None), (1, 1), MESH3) == P(None, None)


def test_cache_spec_seq_fallback():
    # kv=8 cannot shard model=16 -> cache shards SEQUENCE over model
    sp = cache_spec_for(("layers", "batch", "seq", "kv_heads", None),
                        (24, 128, 32768, 8, 128), MESH)
    assert sp == P(None, "data", "model", None, None)
    # kv=16 divides -> heads sharding preferred, seq untouched
    sp = cache_spec_for(("layers", "batch", "seq", "kv_heads", None),
                        (24, 128, 32768, 16, 128), MESH)
    assert sp == P(None, "data", None, "model", None)


def test_dp_profile_rules():
    rules = PROFILES["dp_fsdp"]
    assert spec_for(("batch", None), (256, 4096), MESH, rules) == \
        P(("data", "model"), None)
    assert spec_for(("embed", "mlp"), (2048, 8192), MESH, rules) == P("model", None)


# ------------------------------------------------------- multi-device run
def test_sharded_train_step_matches_single_device(subproc):
    """Golden test: loss on a (4,2) mesh == loss on 1 device (same data)."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_config
from repro.models.schema import init_params, param_specs
from repro.models.transformer import forward_train
from repro.sharding.partition import MeshContext, NULL_CTX
from repro.launch.mesh import make_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

cfg = get_config("internlm2-1.8b", smoke=True).replace(dtype="float32",
                                                       num_kv_heads=2)
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}
loss1, _ = jax.jit(lambda p, b: forward_train(cfg, p, b, NULL_CTX))(params, batch)

mesh = make_mesh((4, 2), ("data", "model"))
ctx = MeshContext(mesh)
pspecs = jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(cfg, mesh),
                      is_leaf=lambda x: isinstance(x, P))
params_s = jax.device_put(params, pspecs)
bs = NamedSharding(mesh, P("data", None))
batch_s = {k: jax.device_put(v, bs) for k, v in batch.items()}
loss2, _ = jax.jit(lambda p, b: forward_train(cfg, p, b, ctx))(params_s, batch_s)
err = abs(float(loss1) - float(loss2))
assert err < 2e-4, (float(loss1), float(loss2))
print("SHARDED_OK", float(loss1), float(loss2))
""")
    assert "SHARDED_OK" in out


def test_moe_expert_parallel_matches_local(subproc):
    """EP shard_map MoE on (2,4) mesh == single-device MoE."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_config
from repro.models.schema import init_params, param_specs
from repro.models.transformer import forward_train
from repro.sharding.partition import MeshContext, NULL_CTX
from repro.launch.mesh import make_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

cfg = get_config("moonshot-v1-16b-a3b", smoke=True).replace(
    dtype="float32", capacity_factor=100.0)
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size)}
loss1, _ = jax.jit(lambda p, b: forward_train(cfg, p, b, NULL_CTX))(params, batch)
mesh = make_mesh((2, 4), ("data", "model"))
ctx = MeshContext(mesh)
pspecs = jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(cfg, mesh),
                      is_leaf=lambda x: isinstance(x, P))
params_s = jax.device_put(params, pspecs)
bs = NamedSharding(mesh, P("data", None))
batch_s = {k: jax.device_put(v, bs) for k, v in batch.items()}
loss2, _ = jax.jit(lambda p, b: forward_train(cfg, p, b, ctx))(params_s, batch_s)
err = abs(float(loss1) - float(loss2))
assert err < 5e-4, (float(loss1), float(loss2))
print("MOE_EP_OK")
""")
    assert "MOE_EP_OK" in out


def test_kv_sharded_flash_decode_matches_reference(subproc):
    """Flash-decoding (seq-sharded cache + distributed softmax) on a
    (2,4) mesh must equal single-device decode attention."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_config
from repro.models.attention import (decode_attention,
                                    kv_sharded_decode_attention)
from repro.sharding.partition import MeshContext
from repro.launch.mesh import make_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

cfg = get_config("internlm2-1.8b", smoke=True).replace(
    dtype="float32", num_heads=6, num_kv_heads=3, head_dim=16)
mesh = make_mesh((2, 4), ("data", "model"))
ctx = MeshContext(mesh)
key = jax.random.PRNGKey(0)
B, Smax, H, Hk, dh = 4, 32, 6, 3, 16
q = jax.random.normal(key, (B, 1, H, dh))
kc = jax.random.normal(key, (B, Smax, Hk, dh))
vc = jax.random.normal(key, (B, Smax, Hk, dh))
kn = jax.random.normal(key, (B, 1, Hk, dh))
vn = jax.random.normal(key, (B, 1, Hk, dh))
pos = jnp.int32(17)

# reference: update then dense decode attention
kk = kc.at[:, 17].set(kn[:, 0]); vv = vc.at[:, 17].set(vn[:, 0])
ref = decode_attention(q, kk, vv, pos, scale=dh ** -0.5)

cspec = NamedSharding(mesh, P("data", "model", None, None))
out, k2, v2 = jax.jit(lambda *a: kv_sharded_decode_attention(cfg, ctx, *a))(
    jax.device_put(q, NamedSharding(mesh, P("data", None, None, None))),
    jax.device_put(kc, cspec), jax.device_put(vc, cspec), kn, vn, pos)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
np.testing.assert_allclose(np.asarray(k2), np.asarray(kk), rtol=1e-6, atol=1e-6)
print("FLASH_DECODE_OK")
""")
    assert "FLASH_DECODE_OK" in out
