"""Mini dry-run: the full lower+compile+analyze pipeline on an 8-device
mesh with smoke configs (the 512-device production sweep runs via
``python -m repro.launch.dryrun``; see EXPERIMENTS.md §Dry-run)."""

import pytest


def test_hlo_analysis_known_flops():
    import jax
    import jax.numpy as jnp
    from repro.launch import hlo_analysis

    def f(x, w):
        def body(c, _):
            return c @ w, None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = jax.jit(f).lower(x, x).compile()
    rep = hlo_analysis.analyze(compiled.as_text())
    assert rep.flops == pytest.approx(2 * 256**3 * 7, rel=1e-6)


def test_hlo_analysis_collectives_counted(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch import hlo_analysis
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("model",))
def f(x, w):
    y = x @ w
    return jax.lax.with_sharding_constraint(y, NamedSharding(mesh, P(None, None)))
c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, None)),
                             NamedSharding(mesh, P("model", None)))) \\
    .lower(jax.ShapeDtypeStruct((64, 512), jnp.bfloat16),
           jax.ShapeDtypeStruct((512, 256), jnp.bfloat16)).compile()
rep = hlo_analysis.analyze(c.as_text())
assert rep.collective_bytes.get("all-reduce", 0) > 0, rep.collective_bytes
# CPU promotes the bf16 AR to f32; corrected bytes are half of raw
raw = rep.collective_bytes_raw["all-reduce"]
assert rep.collective_bytes["all-reduce"] == raw / 2
print("COLL_OK")
""")
    assert "COLL_OK" in out


@pytest.mark.parametrize("arch,kind", [
    ("internlm2-1.8b", "train"),
    ("moonshot-v1-16b-a3b", "train"),
    ("jamba-v0.1-52b", "decode"),
    ("whisper-tiny", "prefill"),
])
def test_mini_dryrun_smoke_configs(subproc, arch, kind):
    """Smoke-config versions of the dry-run cells compile on a (4,2) mesh."""
    out = subproc(f"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.registry import get_config
from repro.models import transformer
from repro.models.schema import abstract_params, param_specs
from repro.sharding.partition import MeshContext
from repro.training.step import make_train_step, abstract_opt_state, opt_state_specs
from repro.launch.mesh import make_mesh

cfg = get_config("{arch}", smoke=True)
mesh = make_mesh((4, 2), ("data", "model"))
ctx = MeshContext(mesh)
named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                               is_leaf=lambda x: isinstance(x, P))
params_abs = abstract_params(cfg)
pspecs = param_specs(cfg, mesh)
B, S = 8, 32
kind = "{kind}"
if kind == "train":
    step_fn, opt = make_train_step(cfg, ctx)
    opt_abs = abstract_opt_state(cfg, opt)
    ospecs = opt_state_specs(cfg, opt, mesh)
    batch = {{"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
              "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}}
    if cfg.is_encdec:
        batch["frame_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_positions, cfg.d_model), jnp.dtype(cfg.dtype))
    c = jax.jit(step_fn, in_shardings=(named(pspecs), named(ospecs), None),
                donate_argnums=(0, 1)).lower(params_abs, opt_abs, batch).compile()
elif kind == "prefill":
    batch = {{"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}}
    if cfg.is_encdec:
        batch["frame_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_positions, cfg.d_model), jnp.dtype(cfg.dtype))
    fn = lambda p, b: transformer.prefill(cfg, p, b, ctx, max_len=S)
    c = jax.jit(fn, in_shardings=(named(pspecs), None)).lower(params_abs, batch).compile()
else:
    cache = transformer.init_cache(cfg, B, S, abstract=True)
    fn = lambda p, cch, t, pos: transformer.decode_step(cfg, p, cch, t, pos, ctx)
    c = jax.jit(fn).lower(params_abs, cache,
                          jax.ShapeDtypeStruct((B, 1), jnp.int32),
                          jax.ShapeDtypeStruct((), jnp.int32)).compile()
assert c.memory_analysis() is not None
ca = c.cost_analysis() or {{}}
if isinstance(ca, (list, tuple)):  # jax<0.5 returns a per-device list
    ca = ca[0] if ca else {{}}
assert ca.get("flops", 0) >= 0
print("MINI_DRYRUN_OK")
""")
    assert "MINI_DRYRUN_OK" in out
