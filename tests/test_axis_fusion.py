"""Axis-aware fusion tests (planner v3) — row-wise reductions over 2-D
operands.

Covers: ``(B,)``-shaped lazy row reduces and their launch schedules
(batched softmax — stable included — is exactly 2 launches), same-wave
``_acc`` chaining, common-subexpression hoisting in generated sources,
broadcasting leaves of unequal length (``(B, 1)`` / ``(N,)`` / scalar)
inside one epilogue, int32/float64 dtype faithfulness, 2-D shape
bucketing (driver reuse across a size sweep, per-bucket-pair tuning),
the model-level `fused_softmax` batched path, and the planner-backed
`rtcg_rmsnorm` against the hand-written Pallas kernel — with
property-style sweeps across batch sizes and bucket-boundary row
lengths.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_stub import given, settings, st

import repro.core.array as ga
from repro.core import backends, dispatch

rng = np.random.default_rng(7)

# col-bucket boundary: ceil(N/128) lane groups, bucket flips at pow2 groups
BOUNDARY_NS = (1023, 1024, 1025)
BATCHES = (1, 7, 32)


@pytest.fixture(scope="module", params=["pallas", "xla"], autouse=True)
def rtcg_backend(request):
    """Run the whole axis-aware suite once per execution backend (PR 4):
    row-wave schedules, `_acc` chaining, broadcast-arg binding and
    bucket-reuse guarantees must hold identically on pallas and xla."""
    import os

    old = os.environ.get("REPRO_BACKEND")
    os.environ["REPRO_BACKEND"] = request.param
    yield request.param
    if old is None:
        os.environ.pop("REPRO_BACKEND", None)
    else:
        os.environ["REPRO_BACKEND"] = old


def _launches(fn):
    with dispatch.count_launches() as c:
        out = fn()
    return out, c.delta


# ------------------------------------------------- row-wise reductions
@pytest.mark.parametrize("B", BATCHES)
@pytest.mark.parametrize("n", BOUNDARY_NS)
def test_row_reduce_shapes_and_values(B, n):
    x = rng.standard_normal((B, n)).astype(np.float32)
    X = ga.to_gpu(x)
    s = X.sum(axis=-1)
    assert s.shape == (B,)
    got, delta = _launches(lambda: s.value)
    assert delta == 1
    np.testing.assert_allclose(np.asarray(got), x.sum(-1), atol=1e-2)
    mx, delta = _launches(lambda: X.max(axis=-1).value)
    assert delta == 1
    np.testing.assert_allclose(np.asarray(mx), x.max(-1), rtol=1e-6)


@pytest.mark.parametrize("B", BATCHES)
@pytest.mark.parametrize("n", BOUNDARY_NS)
def test_batched_softmax_exactly_two_launches(B, n):
    """The acceptance contract: a whole (B, N) batch through the planner
    is ONE row wave + ONE fused 2-D epilogue — for stable softmax too
    (max and shifted-exp sum share the wave via in-kernel chaining)."""
    x = (rng.standard_normal((B, n)) * 4).astype(np.float32)
    X = ga.to_gpu(x)
    ref = np.asarray(jax.nn.softmax(jnp.asarray(x), axis=-1))
    sm, delta = _launches(lambda: ga.softmax(X).value)
    assert delta == 2
    np.testing.assert_allclose(np.asarray(sm), ref, atol=1e-5)
    sm2, delta2 = _launches(lambda: ga.softmax(X, stable=True).value)
    assert delta2 == 2
    np.testing.assert_allclose(np.asarray(sm2), ref, atol=1e-5)


def test_stable_softmax_single_wave_structure():
    """max + shifted-exp-sum land in ONE wave (dependency resolved as an
    in-kernel _acc reference), not two dependent launches."""
    x = rng.standard_normal((4, 600)).astype(np.float32)
    X = ga.to_gpu(x)
    sm = ga.softmax(X, stable=True)
    sched = ga.plan_many([sm])
    assert len(sched.steps) == 1
    assert len(sched.steps[0].nodes) == 2         # max + shifted sum
    assert len(sched.epilogues) == 1
    assert sched.kernel_launches == 2
    snips = sched.steps[0].snippet
    assert any("_acc0" in s for s in snips)       # same-wave chaining


def test_row_mean_host_folds():
    """.mean(axis=-1) = row-sum wave + /n on the host: 1 launch, (B,)."""
    x = rng.standard_normal((5, 700)).astype(np.float32)
    X = ga.to_gpu(x)
    m = X.mean(axis=-1)
    assert m.shape == (5,)
    got, delta = _launches(lambda: m.value)
    assert delta == 1
    np.testing.assert_allclose(np.asarray(got), x.mean(-1), atol=1e-5)


def test_row_reduce_unfused_baseline():
    """fuse=False materializes the map first: 2 launches, same numbers."""
    x = rng.standard_normal((3, 500)).astype(np.float32)
    X = ga.to_gpu(x)
    got, delta = _launches(lambda: (X * 2 + 1).sum(axis=-1, fuse=False).value)
    assert delta == 2
    np.testing.assert_allclose(np.asarray(got), (x * 2 + 1).sum(-1), atol=1e-2)


# --------------------------------------------------- dtype faithfulness
@pytest.mark.parametrize("B", (1, 7))
@pytest.mark.parametrize("n", BOUNDARY_NS)
def test_int32_row_reductions_exact(B, n):
    xi = rng.integers(-1000, 1000, (B, n)).astype(np.int32)
    XI = ga.to_gpu(xi)
    s = XI.sum(axis=-1)
    assert jnp.dtype(s.dtype) == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(s.value), xi.astype(np.int64).sum(-1).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(XI.max(axis=-1).value), xi.max(-1))
    np.testing.assert_array_equal(np.asarray(XI.min(axis=-1).value), xi.min(-1))


def test_float64_row_plans_canonicalize():
    """float64 leaves follow jax_enable_x64 (canonical dtype), and the
    row schedule stays correct either way."""
    x = rng.standard_normal((4, 300))
    X = ga.to_gpu(x)
    want = jnp.dtype(jax.dtypes.canonicalize_dtype(jnp.float64))
    assert jnp.dtype(X.dtype) == want
    got, delta = _launches(lambda: (X.exp() / X.exp().sum(axis=-1)).value)
    assert delta == 2
    ref = jax.nn.softmax(jnp.asarray(x).astype(want), axis=-1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


# --------------------------------------------- broadcasting leaves
def test_broadcast_leaves_in_one_epilogue():
    """(B,1)-vs-(B,N), (N,)-vs-(B,N) and 1-element leaves fuse into one
    kernel instead of raising on mismatched sizes."""
    B, N = 6, 400
    x = rng.standard_normal((B, N)).astype(np.float32)
    w = rng.standard_normal(N).astype(np.float32)
    c = rng.standard_normal((B, 1)).astype(np.float32)
    one = np.asarray([2.5], np.float32)
    X, W, C = ga.to_gpu(x), ga.to_gpu(w), ga.to_gpu(c)
    out, delta = _launches(lambda: (X * W + C - ga.to_gpu(one)).value)
    assert delta == 1                     # ONE fused row-layout kernel
    np.testing.assert_allclose(np.asarray(out), x * w + c - 2.5, atol=1e-5)


def test_broadcast_leaf_kind_classification():
    assert ga._leaf_kind(np.zeros((6, 400), np.float32), 6, 400) == "full"
    assert ga._leaf_kind(np.zeros((6, 1), np.float32), 6, 400) == "row"
    assert ga._leaf_kind(np.zeros((400,), np.float32), 6, 400) == "col"
    assert ga._leaf_kind(np.zeros((1, 400), np.float32), 6, 400) == "col"
    assert ga._leaf_kind(np.zeros((1,), np.float32), 6, 400) == "scalar"
    with pytest.raises(ValueError):
        ga._leaf_kind(np.zeros((3, 7), np.float32), 6, 400)


def test_reduce_free_broadcast_chain_plans_row_layout():
    """v1 plan() upgrades to the row layout when leaves broadcast."""
    x = rng.standard_normal((3, 200)).astype(np.float32)
    w = rng.standard_normal(200).astype(np.float32)
    p = ga.plan((ga.to_gpu(x) * ga.to_gpu(w))._expr)
    assert p.axis == -1 and p.geometry == (3, 200)
    np.testing.assert_allclose(np.asarray(p.launch()), x * w, rtol=1e-5)


# ------------------------------------------------- CSE in generated source
def test_cse_sibling_row_stats_share_one_chain():
    x = rng.standard_normal((4, 900)).astype(np.float32)
    X = ga.to_gpu(x)
    chain = X * 2 + 1
    sched = ga.plan_many([chain.min(axis=-1), chain.max(axis=-1),
                          chain.sum(axis=-1)])
    assert sched.kernel_launches == 1
    wave = sched.steps[0]
    assert len(wave.prelude) == 1         # the chain hoisted once
    assert wave.snippet == ["_t0"] * 3    # all accumulators reuse it
    (lo, hi, tot), delta = _launches(sched.launch)
    assert delta == 1
    ref = x * 2 + 1
    np.testing.assert_allclose(np.asarray(lo), ref.min(-1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hi), ref.max(-1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(tot), ref.sum(-1), atol=1e-2)


def test_cse_across_epilogue_roots():
    """Structurally-equal subtrees built twice hoist into one temp."""
    x = rng.standard_normal(800).astype(np.float32)
    X = ga.to_gpu(x)
    sched = ga.plan_many([X.exp() * 2, X.exp() + 1])   # two distinct exp nodes
    epi = sched.epilogues[0]
    assert len(epi.prelude) == 1 and "expf" in epi.prelude[0]
    a, b = sched.launch()
    np.testing.assert_allclose(np.asarray(a), np.exp(x) * 2, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(b), np.exp(x) + 1, rtol=1e-5)


# --------------------------------------------------- 2-D bucketing
def test_row_driver_reuse_across_bucket():
    """An (B, N) sweep inside one (batch, row-length) bucket pair reuses
    ONE compiled driver per generated kernel — the 2-D bucketing bound."""
    X0 = ga.to_gpu(rng.standard_normal((8, 900)).astype(np.float32))
    (X0.tanh().sum(axis=-1)).value          # warm: compile wave driver
    c0 = dispatch.compile_count()
    for B, N in ((8, 899), (7, 950), (5, 1000), (8, 1024)):
        x = rng.standard_normal((B, N)).astype(np.float32)
        v = ga.to_gpu(x).tanh().sum(axis=-1).value
        np.testing.assert_allclose(np.asarray(v), np.tanh(x).sum(-1), atol=1e-3)
    assert dispatch.compile_count() == c0   # same bucket pair: zero rebuilds


def test_bucket_pair_helpers():
    assert dispatch.bucket_cols(1) == 128
    assert dispatch.bucket_cols(1024) == 1024
    assert dispatch.bucket_cols(1025) == 2048
    assert dispatch.rc_bucket(7, 900) == dispatch.rc_bucket(8, 1024)
    assert dispatch.rc_bucket(7, 900) != dispatch.rc_bucket(9, 900)
    assert dispatch.bucket_batch(1, 1) == 1
    assert dispatch.bucket_batch(7, 4) == 8


def test_row_reduction_autotune_per_bucket_pair(tmp_path):
    from repro.core.cache import DiskCache
    from repro.core.reduction import ReductionKernel

    rowsum = ReductionKernel(np.float32, "0", "a+b", "x[i]", "float *x",
                             name="tunerow", axis=-1)
    cache = DiskCache("tune", root=tmp_path)
    v = jnp.asarray(rng.standard_normal((16, 3000)).astype(np.float32))
    rep = rowsum.autotune(v, cache=cache, repeats=1, warmup=1)
    be = backends.get_backend().name
    assert rowsum._tuned[(be, dispatch.rc_bucket(16, 3000))] == rep.best["block_rows"]
    # same bucket pair, different exact shape -> cached, no re-timing
    v2 = jnp.asarray(rng.standard_normal((13, 2900)).astype(np.float32))
    rep2 = rowsum.autotune(v2, cache=cache, repeats=1, warmup=1)
    assert rep2.cached and rep2.best == rep.best
    np.testing.assert_allclose(np.asarray(rowsum(v2)),
                               np.asarray(v2).sum(-1), atol=1e-2)


# ------------------------------------------------ model-level wiring
def test_fused_softmax_batched_two_launches():
    from repro.models.layers import fused_softmax

    x = jnp.asarray((rng.standard_normal((16, 512)) * 6).astype(np.float32))
    with dispatch.count_launches() as c:
        out = fused_softmax(x)
    assert c.delta == 2
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jax.nn.softmax(x, axis=-1)),
                               atol=1e-5)
    # >2-D batches flatten to rows; traced inputs still fall back
    x4 = jnp.reshape(x, (2, 2, 4, 512))
    np.testing.assert_allclose(np.asarray(fused_softmax(x4)),
                               np.asarray(jax.nn.softmax(x4, axis=-1)),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(jax.jit(fused_softmax)(x)),
                               np.asarray(jax.nn.softmax(x, axis=-1)),
                               atol=1e-6)


def test_rtcg_rmsnorm_matches_reference_and_kernel():
    from repro.kernels.rmsnorm.ops import rmsnorm as pallas_rmsnorm
    from repro.models.layers import rtcg_rmsnorm

    B, D = 9, 768
    x = rng.standard_normal((B, D)).astype(np.float32)
    w = rng.standard_normal(D).astype(np.float32)
    xj, wj = jnp.asarray(x), jnp.asarray(w)
    with dispatch.count_launches() as c:
        got = rtcg_rmsnorm(xj, wj, eps=1e-6)
    assert c.delta == 2                    # row wave + fused 2-D epilogue
    ref = x / np.sqrt((x * x).mean(-1, keepdims=True) + 1e-6) * w
    np.testing.assert_allclose(np.asarray(got), ref, atol=1e-4)
    np.testing.assert_allclose(np.asarray(pallas_rmsnorm(xj, wj, eps=1e-6)),
                               ref, atol=1e-4)


# ------------------------------------------- property-style sweeps
@given(B=st.integers(1, 12), n=st.integers(450, 650), seed=st.integers(0, 50))
@settings(max_examples=6, deadline=None)
def test_batched_softmax_property(B, n, seed):
    r = np.random.default_rng(seed)
    x = r.standard_normal((B, n)).astype(np.float32)
    X = ga.to_gpu(x)
    sm, delta = _launches(lambda: ga.softmax(X, stable=True).value)
    assert delta == 2
    np.testing.assert_allclose(np.asarray(sm),
                               np.asarray(jax.nn.softmax(jnp.asarray(x), axis=-1)),
                               atol=1e-5)


@given(B=st.integers(1, 10), n=st.integers(100, 400), seed=st.integers(0, 50))
@settings(max_examples=6, deadline=None)
def test_row_variance_property(B, n, seed):
    r = np.random.default_rng(seed)
    x = r.standard_normal((B, n)).astype(np.float32)
    X = ga.to_gpu(x)
    v = (((X - X.mean(axis=-1)) ** 2).mean(axis=-1)).value
    np.testing.assert_allclose(np.asarray(v), x.var(-1), rtol=1e-3, atol=1e-5)
